# pytest: kernel vs ref allclose — the CORE correctness signal.
#
# The Pallas kernel (interpret=True) is swept against the pure-jnp oracle
# over shapes, including non-block-multiple ragged edges, plus a
# hypothesis sweep over random (M, K, N, act).
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import matmul_fused as mk
from compile.kernels import ref


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype("float32"))


@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),
    (2, 3, 4),
    (8, 8, 8),
    (128, 64, 128),          # exact block multiple
    (129, 64, 127),          # ragged both dims
    (5, 600, 7),             # K larger than any block
    (256, 27, 16),           # im2col-conv shaped (3x3x3 patches)
    (1024, 64, 10),          # classifier head shaped
])
@pytest.mark.parametrize("act", ["none", "relu"])
def test_matmul_bias_act_vs_ref(m, k, n, act):
    x, w, b = _rand((m, k), 0), _rand((k, n), 1), _rand((n,), 2)
    got = mk.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 16), (128, 128), (256, 64)])
def test_block_shape_independence(bm, bn):
    """Result must not depend on the tiling choice."""
    x, w, b = _rand((70, 33), 3), _rand((33, 50), 4), _rand((50,), 5)
    got = mk.matmul_bias_act(x, w, b, act="relu", bm=bm, bn=bn)
    want = ref.matmul_bias_act(x, w, b, act="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_matmul_zero_bias_helper():
    x, w = _rand((9, 17), 6), _rand((17, 11), 7)
    np.testing.assert_allclose(np.asarray(mk.matmul(x, w)),
                               np.asarray(x @ w), rtol=1e-5, atol=1e-5)


def test_rejects_bad_act():
    x, w, b = _rand((2, 2), 0), _rand((2, 2), 1), _rand((2,), 2)
    with pytest.raises(AssertionError):
        mk.matmul_bias_act(x, w, b, act="gelu")


def test_rejects_shape_mismatch():
    with pytest.raises(AssertionError):
        mk.matmul_bias_act(_rand((2, 3), 0), _rand((4, 2), 1), _rand((2,), 2))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_sweep(m, k, n, act, seed):
    x = _rand((m, k), seed)
    w = _rand((k, n), seed + 1)
    b = _rand((n,), seed + 2)
    got = mk.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_vmem_footprint_monotone_in_blocks():
    small = mk.vmem_footprint_bytes(1024, 576, 64, bm=64, bn=64)
    big = mk.vmem_footprint_bytes(1024, 576, 64, bm=256, bn=64)
    assert big > small


def test_vmem_footprint_under_budget_for_model_shapes():
    """Every matmul shape the CIFAR models produce must fit 16 MiB VMEM
    with the default blocks (documented in DESIGN.md §Perf)."""
    VMEM = 16 * 1024 * 1024
    shapes = [
        (8 * 32 * 32, 27, 16),    # first conv
        (8 * 32 * 32, 144, 16),   # 16-ch stage
        (8 * 16 * 16, 288, 32),   # 32-ch stage
        (8 * 8 * 8, 576, 64),     # 64-ch stage
        (8, 64, 10),              # head
        (16, 4096, 4096),         # e2e wide MLP
    ]
    for m, k, n in shapes:
        assert mk.vmem_footprint_bytes(m, k, n) < VMEM, (m, k, n)


def test_mxu_utilization_estimate():
    assert mk.mxu_utilization_estimate(128, 64, 128) == 1.0
    assert mk.mxu_utilization_estimate(129, 64, 128) < 0.6
