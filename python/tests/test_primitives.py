# pytest: every L2 primitive's explicit backward vs jax.grad of the pure-jnp
# reference composition. This is what guarantees the Rust coordinator's
# distributed back-propagation (which chains these artifacts) computes the
# same gradients TensorFlow's GradientTape would have.
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype("float32"))


def _close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,c,k,h,w,kk,s", [
    (2, 3, 4, 8, 8, 3, 1),
    (2, 3, 4, 8, 8, 3, 2),
    (1, 16, 32, 16, 16, 3, 2),
    (2, 4, 8, 8, 8, 1, 1),
    (2, 4, 8, 8, 8, 1, 2),
    (3, 5, 7, 6, 6, 3, 1),   # odd sizes
])
def test_conv2d_fwd_vs_ref(n, c, k, h, w, kk, s):
    x, wt = _rand((n, c, h, w), 0), _rand((k, c, kk, kk), 1)
    _close(model.conv2d_fwd(x, wt, stride=s), ref.conv2d(x, wt, stride=s))


@pytest.mark.parametrize("n,c,k,h,w,kk,s", [
    (2, 3, 4, 8, 8, 3, 1),
    (2, 3, 4, 8, 8, 3, 2),
    (2, 4, 8, 8, 8, 1, 2),
])
def test_conv2d_bwd_vs_autodiff(n, c, k, h, w, kk, s):
    x, wt = _rand((n, c, h, w), 2), _rand((k, c, kk, kk), 3)
    gy = _rand(model.conv2d_fwd(x, wt, stride=s).shape, 4)

    def f(xx, ww):
        return jnp.sum(ref.conv2d(xx, ww, stride=s) * gy)

    want_gx, want_gw = jax.grad(f, argnums=(0, 1))(x, wt)
    got_gx, got_gw = model.conv2d_bwd(x, wt, gy, stride=s)
    _close(got_gx, want_gx)
    _close(got_gw, want_gw)


# ---------------------------------------------------------------------------
# batchnorm
# ---------------------------------------------------------------------------

def test_bn_fwd_normalizes():
    x = _rand((4, 3, 8, 8), 5) * 3.0 + 2.0
    y = model.bn_fwd(x, jnp.ones(3), jnp.zeros(3))
    m = np.asarray(y).mean(axis=(0, 2, 3))
    v = np.asarray(y).var(axis=(0, 2, 3))
    assert np.abs(m).max() < 1e-5
    assert np.abs(v - 1.0).max() < 1e-2


def test_bn_bwd_vs_autodiff():
    x, gamma = _rand((4, 3, 8, 8), 6), _rand((3,), 7)
    beta = _rand((3,), 8)
    gy = _rand((4, 3, 8, 8), 9)

    def f(xx, g, b):
        return jnp.sum(ref.batchnorm(xx, g, b) * gy)

    want = jax.grad(f, argnums=(0, 1, 2))(x, gamma, beta)
    got = model.bn_bwd(x, gamma, gy)
    for g, w in zip(got, want):
        _close(g, w)


# ---------------------------------------------------------------------------
# relu / pooling / gap
# ---------------------------------------------------------------------------

def test_relu_bwd_masks():
    x = jnp.asarray([[-1.0, 2.0], [0.0, -3.0]])
    gy = jnp.ones((2, 2))
    got = model.relu_bwd(x, gy)
    assert np.array_equal(np.asarray(got), [[0, 1], [0, 0]])


def test_maxpool2_fwd_bwd_vs_autodiff():
    x = _rand((2, 3, 8, 8), 10)
    gy = _rand((2, 3, 4, 4), 11)
    _close(model.maxpool2_fwd(x), ref.maxpool2(x))
    want = jax.grad(lambda xx: jnp.sum(ref.maxpool2(xx) * gy))(x)
    _close(model.maxpool2_bwd(x, gy), want)


def test_gap_fwd_bwd_vs_autodiff():
    x = _rand((2, 5, 4, 4), 12)
    gy = _rand((2, 5), 13)
    _close(model.gap_fwd(x), ref.gap(x))
    want = jax.grad(lambda xx: jnp.sum(ref.gap(xx) * gy))(x)
    _close(model.gap_bwd(gy, 4, 4), want)


# ---------------------------------------------------------------------------
# dense (+fused relu)
# ---------------------------------------------------------------------------

def test_dense_fwd_bwd_vs_autodiff():
    x, w, b = _rand((4, 7), 14), _rand((7, 5), 15), _rand((5,), 16)
    gy = _rand((4, 5), 17)
    _close(model.dense_fwd(x, w, b), ref.dense(x, w, b))
    want = jax.grad(lambda xx, ww, bb: jnp.sum(ref.dense(xx, ww, bb) * gy),
                    argnums=(0, 1, 2))(x, w, b)
    got = model.dense_bwd(x, w, gy)
    _close(got[0], want[0])
    _close(got[1], want[1])
    _close(got[2], want[2])


def test_dense_relu_fused_vs_composition():
    x, w, b = _rand((4, 7), 18), _rand((7, 5), 19), _rand((5,), 20)
    gy = _rand((4, 5), 21)
    _close(model.dense_relu_fwd(x, w, b), ref.relu(ref.dense(x, w, b)))
    want = jax.grad(
        lambda xx, ww, bb: jnp.sum(ref.relu(ref.dense(xx, ww, bb)) * gy),
        argnums=(0, 1, 2))(x, w, b)
    got = model.dense_relu_bwd(x, w, b, gy)
    for g, wv in zip(got, want):
        _close(g, wv)


# ---------------------------------------------------------------------------
# softmax cross-entropy
# ---------------------------------------------------------------------------

def test_softmax_xent_loss_and_grad():
    logits = _rand((6, 10), 22)
    labels = np.zeros((6, 10), dtype="float32")
    labels[np.arange(6), np.arange(6) % 10] = 1.0
    y = jnp.asarray(labels)
    loss, glogits = model.softmax_xent(logits, y)
    want_loss = -np.mean(
        np.sum(np.asarray(y) * np.log(jax.nn.softmax(logits, axis=1)), axis=1))
    _close(loss, want_loss)
    want_g = jax.grad(
        lambda l: -jnp.mean(jnp.sum(y * jax.nn.log_softmax(l, axis=1), axis=1))
    )(logits)
    _close(glogits, want_g)


def test_softmax_xent_uniform_is_log_c():
    logits = jnp.zeros((4, 10))
    y = jnp.eye(10)[:4]
    loss, _ = model.softmax_xent(logits, y)
    _close(loss, np.log(10.0), tol=1e-5)


# ---------------------------------------------------------------------------
# fused conv+bn+relu (perf path) vs the three-op composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2])
def test_conv_bn_relu_fused_fwd(s):
    x, w = _rand((2, 3, 8, 8), 23), _rand((4, 3, 3, 3), 24)
    gamma, beta = _rand((4,), 25), _rand((4,), 26)
    got = model.conv_bn_relu_fwd(x, w, gamma, beta, stride=s)
    want = ref.relu(ref.batchnorm(ref.conv2d(x, w, stride=s), gamma, beta))
    _close(got, want)


@pytest.mark.parametrize("s", [1, 2])
def test_conv_bn_relu_fused_bwd(s):
    x, w = _rand((2, 3, 8, 8), 27), _rand((4, 3, 3, 3), 28)
    gamma, beta = _rand((4,), 29), _rand((4,), 30)
    gy = _rand(model.conv_bn_relu_fwd(x, w, gamma, beta, stride=s).shape, 31)

    def f(xx, ww, g, b):
        return jnp.sum(ref.relu(ref.batchnorm(ref.conv2d(xx, ww, stride=s), g, b)) * gy)

    want = jax.grad(f, argnums=(0, 1, 2, 3))(x, w, gamma, beta)
    got = model.conv_bn_relu_bwd(x, w, gamma, beta, gy, stride=s)
    for g, wv in zip(got, want):
        _close(g, wv)


# ---------------------------------------------------------------------------
# registry grammar
# ---------------------------------------------------------------------------

def test_parse_registry_line():
    prim, p = model.parse_registry_line("conv3x3 8 16 16 32 32 1 # comment")
    assert prim == "conv3x3"
    assert p == dict(n=8, c=16, k=16, h=32, w=32, s=1)
    assert model.parse_registry_line("   # only comment") is None
    assert model.parse_registry_line("") is None
    with pytest.raises(ValueError):
        model.parse_registry_line("frobnicate 1 2")
    with pytest.raises(ValueError):
        model.parse_registry_line("dense 1 2")  # arity


def test_instance_name_roundtrip():
    name = model.instance_name("dense", dict(n=8, d=64, m=10))
    assert name == "dense_n8_d64_m10"
