"""L2 — the layer-primitive library: JAX forward and explicit-VJP backward
functions for every DNN layer type the Rust coordinator composes at runtime.

Each primitive `p` exports two pure functions:

    p_fwd(params..., x)      -> y (or a tuple)
    p_bwd(params..., x, gy)  -> (gx, gparams...)

The backward functions implement the paper's *partial error* contract
(HyPar-Flow §6.2, Eq. 5-6): they take the upstream partial error `gy` —
exactly what `tape.gradient(..., output_gradients=errors)` consumed in the
TF implementation — and return the partial error `gx` to forward to the
preceding model-partition plus the local parameter gradients.

All FLOP-heavy contractions (dense, im2col conv, and their backward
matmuls) route through the L1 Pallas kernel
(`kernels.matmul_fused.matmul_bias_act`), so the hot path lowers through
Pallas into the exported HLO. Cheap elementwise/reduction ops (BN, ReLU,
pooling, loss) are plain jnp; their backward passes either use `jax.vjp`
(legal: no Pallas inside) or closed forms.

Residual policy: backward recomputes what it needs from (params, x) instead
of shipping residual tensors across the Rust<->HLO boundary. This keeps every
artifact's signature uniform and the Rust-side state machine trivial; the
recompute cost is one BN-normalize or one patch-extraction, never a full
conv.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_fused as mk
from .kernels import ref

BN_EPS = 1e-5


# ---------------------------------------------------------------------------
# im2col helpers
# ---------------------------------------------------------------------------

def _same_pad(kh, kw):
    return [(kh // 2, kh // 2), (kw // 2, kw // 2)]


def _patches(x, kh, kw, stride):
    """x:[N,C,H,W] -> patches [N, C*kh*kw, H', W'] (OIHW-flatten ordering)."""
    return jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), _same_pad(kh, kw),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _nchw_to_mat(t):
    """[N,F,H,W] -> [N*H*W, F]."""
    n, f, h, w = t.shape
    return t.transpose(0, 2, 3, 1).reshape(n * h * w, f)


def _mat_to_nchw(m, n, h, w):
    """[N*H*W, F] -> [N,F,H,W]."""
    f = m.shape[1]
    return m.reshape(n, h, w, f).transpose(0, 3, 1, 2)


# ---------------------------------------------------------------------------
# conv2d (SAME padding, square odd kernel) — the hot spot
# ---------------------------------------------------------------------------

def conv2d_fwd(x, w, *, stride=1):
    """x:[N,C,H,W], w:[K,C,kh,kw] -> y:[N,K,H/s,W/s] via im2col + Pallas."""
    k, c, kh, kw = w.shape
    p = _patches(x, kh, kw, stride)            # [N, F, H', W']
    n, f, ho, wo = p.shape
    pmat = _nchw_to_mat(p)                     # [N*H'*W', F]
    wmat = w.reshape(k, f).T                   # [F, K]
    ymat = mk.matmul(pmat, wmat)               # Pallas
    return _mat_to_nchw(ymat, n, ho, wo)


def conv2d_bwd(x, w, gy, *, stride=1):
    """Returns (gx, gw). Both backward contractions go through Pallas."""
    k, c, kh, kw = w.shape
    n = x.shape[0]
    _, _, ho, wo = gy.shape
    f = c * kh * kw

    def extract(xx):
        return _patches(xx, kh, kw, stride)

    p, vjp_p = jax.vjp(extract, x)             # patch extraction is pure XLA
    pmat = _nchw_to_mat(p)                     # [M, F], M = N*H'*W'
    gymat = _nchw_to_mat(gy)                   # [M, K]

    # gw = pmat^T @ gymat : [F, K] -> reshape to [K, C, kh, kw]
    gwmat = mk.matmul(pmat.T, gymat)           # Pallas
    gw = gwmat.T.reshape(k, c, kh, kw)

    # gpatches = gymat @ wmat^T : [M, F] -> col2im via vjp of extraction
    wmat = w.reshape(k, f)                     # [K, F]
    gpmat = mk.matmul(gymat, wmat)             # Pallas: [M,K]@[K,F]
    gp = _mat_to_nchw(gpmat, n, ho, wo)
    (gx,) = vjp_p(gp)
    return gx, gw


# ---------------------------------------------------------------------------
# batchnorm (train mode, batch statistics)
# ---------------------------------------------------------------------------

def bn_fwd(x, gamma, beta):
    return ref.batchnorm(x, gamma, beta, eps=BN_EPS)


def bn_bwd(x, gamma, gy):
    """(gx, ggamma, gbeta) via jax.vjp of the pure-jnp forward."""
    def f(xx, g, b):
        return ref.batchnorm(xx, g, b, eps=BN_EPS)

    beta = jnp.zeros_like(gamma)               # beta does not affect gx/ggamma
    _, vjp = jax.vjp(f, x, gamma, beta)
    gx, ggamma, gbeta = vjp(gy)
    return gx, ggamma, gbeta


# ---------------------------------------------------------------------------
# relu
# ---------------------------------------------------------------------------

def relu_fwd(x):
    return jnp.maximum(x, 0.0)


def relu_bwd(x, gy):
    return jnp.where(x > 0, gy, 0.0)


# ---------------------------------------------------------------------------
# 2x2 max pooling (VGG)
# ---------------------------------------------------------------------------

def maxpool2_fwd(x):
    return ref.maxpool2(x)


def maxpool2_bwd(x, gy):
    _, vjp = jax.vjp(ref.maxpool2, x)
    (gx,) = vjp(gy)
    return gx


# ---------------------------------------------------------------------------
# global average pool
# ---------------------------------------------------------------------------

def gap_fwd(x):
    return ref.gap(x)


def gap_bwd(gy, h, w):
    """gx from gy alone — the input is only needed for its (static) shape,
    so the artifact takes just gy (JAX lowering DCEs unused args, which
    would desync the manifest; aot.py asserts against that)."""
    n, c = gy.shape
    return jnp.broadcast_to(gy[:, :, None, None], (n, c, h, w)) / (h * w)


# ---------------------------------------------------------------------------
# dense (+bias)
# ---------------------------------------------------------------------------

def dense_fwd(x, w, b):
    return mk.matmul_bias_act(x, w, b, act="none")


def dense_relu_fwd(x, w, b):
    """Fused dense+ReLU (single Pallas launch with relu epilogue)."""
    return mk.matmul_bias_act(x, w, b, act="relu")


def dense_bwd(x, w, gy):
    gw = mk.matmul(x.T, gy)                    # [D,N]@[N,M]
    gx = mk.matmul(gy, w.T)                    # [N,M]@[M,D]
    gb = jnp.sum(gy, axis=0)
    return gx, gw, gb


def dense_relu_bwd(x, w, b, gy):
    """Backward of fused dense+ReLU (recomputes the pre-activation mask)."""
    y = mk.matmul_bias_act(x, w, b, act="none")
    g = jnp.where(y > 0, gy, 0.0)
    return dense_bwd(x, w, g)


# ---------------------------------------------------------------------------
# softmax cross-entropy: loss and glogits in one artifact
# ---------------------------------------------------------------------------

def softmax_xent(logits, y_onehot):
    return ref.softmax_xent(logits, y_onehot)


# ---------------------------------------------------------------------------
# fused conv3x3 + BN + ReLU (perf variant; used by the optimized engine path)
# ---------------------------------------------------------------------------

def conv_bn_relu_fwd(x, w, gamma, beta, *, stride=1):
    y = conv2d_fwd(x, w, stride=stride)
    z = bn_fwd(y, gamma, beta)
    return jnp.maximum(z, 0.0)


def conv_bn_relu_bwd(x, w, gamma, beta, gy, *, stride=1):
    """(gx, gw, ggamma, gbeta) — recomputes y and z, chains explicit bwds."""
    y = conv2d_fwd(x, w, stride=stride)
    z = bn_fwd(y, gamma, beta)
    gz = jnp.where(z > 0, gy, 0.0)
    gyy, ggamma, gbeta = bn_bwd(y, gamma, gz)
    gx, gw = conv2d_bwd(x, w, gyy, stride=stride)
    return gx, gw, ggamma, gbeta


# ---------------------------------------------------------------------------
# Primitive catalog: name -> (builder of (fn, arg_specs)).
#
# Instance grammar (one per line in the registry):
#   conv3x3   n c k h w s     conv1x1   n c k h w s
#   convbnrelu n c k h w s    bn        n c h w
#   relu4     n c h w         relu2     n d
#   maxpool2  n c h w         gap       n c h w
#   dense     n d m           denserelu n d m
#   softmaxxent n c
# Each instance expands to <name>.fwd and <name>.bwd artifacts
# (softmaxxent has only fwd: it already returns (loss, glogits)).
# ---------------------------------------------------------------------------

F32 = jnp.float32


def _s(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def _conv_specs(p, kk):
    n, c, k, h, w, s = p["n"], p["c"], p["k"], p["h"], p["w"], p["s"]
    ho, wo = -(-h // s), -(-w // s)
    x, wt, gy = _s(n, c, h, w), _s(k, c, kk, kk), _s(n, k, ho, wo)
    return x, wt, gy


def instance(prim, p):
    """Return list of (suffix, fn, arg_specs) for one registry instance."""
    if prim in ("conv3x3", "conv1x1"):
        kk = 3 if prim == "conv3x3" else 1
        x, w, gy = _conv_specs(p, kk)
        s = p["s"]
        return [
            ("fwd", lambda x, w: (conv2d_fwd(x, w, stride=s),), [x, w]),
            ("bwd", lambda x, w, gy: conv2d_bwd(x, w, gy, stride=s), [x, w, gy]),
        ]
    if prim == "convbnrelu":
        x, w, gy = _conv_specs(p, 3)
        s = p["s"]
        g = _s(p["k"])
        return [
            ("fwd", lambda x, w, ga, be: (conv_bn_relu_fwd(x, w, ga, be, stride=s),),
             [x, w, g, g]),
            ("bwd", lambda x, w, ga, be, gy: conv_bn_relu_bwd(x, w, ga, be, gy, stride=s),
             [x, w, g, g, gy]),
        ]
    if prim == "bn":
        n, c, h, w = p["n"], p["c"], p["h"], p["w"]
        x, g = _s(n, c, h, w), _s(c)
        return [
            ("fwd", lambda x, ga, be: (bn_fwd(x, ga, be),), [x, g, g]),
            ("bwd", lambda x, ga, gy: bn_bwd(x, ga, gy), [x, g, x]),
        ]
    if prim == "relu4":
        x = _s(p["n"], p["c"], p["h"], p["w"])
        return [
            ("fwd", lambda x: (relu_fwd(x),), [x]),
            ("bwd", lambda x, gy: (relu_bwd(x, gy),), [x, x]),
        ]
    if prim == "relu2":
        x = _s(p["n"], p["d"])
        return [
            ("fwd", lambda x: (relu_fwd(x),), [x]),
            ("bwd", lambda x, gy: (relu_bwd(x, gy),), [x, x]),
        ]
    if prim == "maxpool2":
        n, c, h, w = p["n"], p["c"], p["h"], p["w"]
        x, gy = _s(n, c, h, w), _s(n, c, h // 2, w // 2)
        return [
            ("fwd", lambda x: (maxpool2_fwd(x),), [x]),
            ("bwd", lambda x, gy: (maxpool2_bwd(x, gy),), [x, gy]),
        ]
    if prim == "gap":
        n, c, h, w = p["n"], p["c"], p["h"], p["w"]
        x, gy = _s(n, c, h, w), _s(n, c)
        return [
            ("fwd", lambda x: (gap_fwd(x),), [x]),
            ("bwd", lambda gy: (gap_bwd(gy, h, w),), [gy]),
        ]
    if prim in ("dense", "denserelu"):
        n, d, m = p["n"], p["d"], p["m"]
        x, w, b, gy = _s(n, d), _s(d, m), _s(m), _s(n, m)
        if prim == "dense":
            return [
                ("fwd", lambda x, w, b: (dense_fwd(x, w, b),), [x, w, b]),
                ("bwd", lambda x, w, gy: dense_bwd(x, w, gy), [x, w, gy]),
            ]
        return [
            ("fwd", lambda x, w, b: (dense_relu_fwd(x, w, b),), [x, w, b]),
            ("bwd", lambda x, w, b, gy: dense_relu_bwd(x, w, b, gy), [x, w, b, gy]),
        ]
    if prim == "softmaxxent":
        n, c = p["n"], p["c"]
        x, y = _s(n, c), _s(n, c)
        return [("fwd", lambda l, y: softmax_xent(l, y), [x, y])]
    raise ValueError(f"unknown primitive {prim!r}")


#: parameter-name order per primitive (registry line format).
PARAM_ORDER = {
    "conv3x3": ["n", "c", "k", "h", "w", "s"],
    "conv1x1": ["n", "c", "k", "h", "w", "s"],
    "convbnrelu": ["n", "c", "k", "h", "w", "s"],
    "bn": ["n", "c", "h", "w"],
    "relu4": ["n", "c", "h", "w"],
    "relu2": ["n", "d"],
    "maxpool2": ["n", "c", "h", "w"],
    "gap": ["n", "c", "h", "w"],
    "dense": ["n", "d", "m"],
    "denserelu": ["n", "d", "m"],
    "softmaxxent": ["n", "c"],
}


def parse_registry_line(line):
    """'conv3x3 8 16 16 32 32 1' -> ('conv3x3', {...}) or None for blanks."""
    line = line.split("#", 1)[0].strip()
    if not line:
        return None
    parts = line.split()
    prim = parts[0]
    if prim not in PARAM_ORDER:
        raise ValueError(f"unknown primitive {prim!r} in registry line {line!r}")
    names = PARAM_ORDER[prim]
    if len(parts) - 1 != len(names):
        raise ValueError(
            f"{prim} expects {len(names)} params {names}, got {parts[1:]} in {line!r}")
    return prim, dict(zip(names, map(int, parts[1:])))


def instance_name(prim, p):
    """Canonical artifact base name, e.g. conv3x3_n8_c16_k16_h32_w32_s1."""
    return prim + "".join(f"_{k}{p[k]}" for k in PARAM_ORDER[prim])
