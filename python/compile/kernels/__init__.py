# L1: Pallas kernel(s) for the paper's compute hot-spot.
from . import matmul_fused, ref  # noqa: F401
