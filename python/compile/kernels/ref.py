"""Pure-jnp oracles for the Pallas kernel and every L2 primitive.

These are the correctness ground truth: pytest asserts the Pallas kernel and
the exported primitives against these implementations (which never touch
Pallas), and the Rust integration tests re-check a frozen subset of the same
numbers end-to-end through PJRT.
"""

import jax
import jax.numpy as jnp


def matmul_bias_act(x, w, b, act="none"):
    y = x @ w + b[None, :]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    return y


def conv2d(x, w, stride=1):
    """NCHW conv, SAME padding (odd kernels). x:[N,C,H,W], w:[K,C,kh,kw]."""
    kh, kw = w.shape[2], w.shape[3]
    pad = ((kh // 2, kh // 2), (kw // 2, kw // 2))
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=pad,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def batchnorm(x, gamma, beta, eps=1e-5):
    """Train-mode BN over (N, H, W) per channel. x:[N,C,H,W]."""
    mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
    xhat = (x - mean) / jnp.sqrt(var + eps)
    return xhat * gamma[None, :, None, None] + beta[None, :, None, None]


def relu(x):
    return jnp.maximum(x, 0.0)


def maxpool2(x):
    """2x2 max pool, stride 2. x:[N,C,H,W] with even H, W."""
    n, c, h, w = x.shape
    x = x.reshape(n, c, h // 2, 2, w // 2, 2)
    return x.max(axis=(3, 5))


def gap(x):
    """Global average pool: [N,C,H,W] -> [N,C]."""
    return jnp.mean(x, axis=(2, 3))


def dense(x, w, b):
    return x @ w + b[None, :]


def softmax_xent(logits, y_onehot):
    """Mean softmax cross-entropy. Returns (scalar loss, dloss/dlogits)."""
    lse = jax.scipy.special.logsumexp(logits, axis=1, keepdims=True)
    logp = logits - lse
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=1))
    probs = jnp.exp(logp)
    glogits = (probs - y_onehot) / logits.shape[0]
    return loss, glogits
