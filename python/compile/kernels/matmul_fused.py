"""L1 — the Pallas hot-spot kernel: tiled matmul + bias + activation.

Every FLOP-heavy primitive in the L2 layer library (dense layers and im2col
convolutions, forward *and* backward) funnels through this kernel, so the
DNN hot path lowers through Pallas into the exported HLO.

Design (TPU-shaped, run under interpret=True for CPU-PJRT):
  - Grid over (M/bm, N/bn) output tiles; the full K dimension is resident in
    VMEM per tile. For this workload K = C*kh*kw <= 4608 (ResNet/VGG im2col)
    or the MLP hidden width, so the working set per tile
      bm*K + K*bn + bm*bn floats
    stays well under a TPU core's ~16 MiB VMEM (see DESIGN.md §Perf for the
    footprint table). This trades a K-loop + accumulator scratch for a single
    fused multiply, which keeps the MXU pipeline busy with one
    (bm x K) @ (K x bn) contraction per grid step.
  - Block sizes default to (bm, bn) = (128, 128): multiples of the (8, 128)
    f32 lane tile and the 128x128 MXU systolic array.
  - Inputs are zero-padded up to block multiples by the wrapper; the output
    is sliced back. This keeps the kernel branch-free (no masking).
  - fp32 accumulate; `act` fuses the epilogue (none / relu) so conv+relu and
    dense+relu never materialize the pre-activation in HBM.

Interpret mode note: real-TPU lowering emits a Mosaic custom-call that the
CPU PJRT plugin cannot execute, so pallas_call(..., interpret=True) is
mandatory here (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default output-tile block sizes: MXU-aligned.
BLOCK_M = 128
BLOCK_N = 128

VALID_ACTS = ("none", "relu")


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    """One (bm, bn) output tile: y = act(x @ w + b)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn"))
def matmul_bias_act(x, w, b, *, act="none", bm=BLOCK_M, bn=BLOCK_N):
    """y = act(x @ w + b) with x:[M,K], w:[K,N], b:[N] -> y:[M,N] (f32).

    The Pallas grid covers the padded output; padding is sliced off before
    returning, so arbitrary M/K/N are accepted.
    """
    assert act in VALID_ACTS, f"act must be one of {VALID_ACTS}, got {act!r}"
    assert x.ndim == 2 and w.ndim == 2 and b.ndim == 1
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    assert b.shape[0] == n, f"bias {b.shape} vs N={n}"

    # Shrink blocks for small problems so tiny shapes don't pad 128x.
    bm_eff = min(bm, max(8, 1 << (max(m - 1, 1)).bit_length()))
    bn_eff = min(bn, max(8, 1 << (max(n - 1, 1)).bit_length()))

    xp = _pad_to(x.astype(jnp.float32), 0, bm_eff)
    wp = _pad_to(w.astype(jnp.float32), 1, bn_eff)
    bp = _pad_to(b.astype(jnp.float32), 0, bn_eff)
    mp, np_ = xp.shape[0], wp.shape[1]

    grid = (mp // bm_eff, np_ // bn_eff)
    out = pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_eff, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_eff), lambda i, j: (0, j)),
            pl.BlockSpec((bn_eff,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_eff, bn_eff), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def matmul(x, w, *, bm=BLOCK_M, bn=BLOCK_N):
    """Plain x @ w through the fused kernel (zero bias, no activation)."""
    b = jnp.zeros((w.shape[1],), jnp.float32)
    return matmul_bias_act(x, w, b, act="none", bm=bm, bn=bn)


def vmem_footprint_bytes(m, k, n, *, bm=BLOCK_M, bn=BLOCK_N):
    """Estimated VMEM bytes for one grid step (f32): x-tile + w-tile + out.

    Used by DESIGN.md §Perf / the block-shape sweep to pick (bm, bn) that fit
    a TPU core's ~16 MiB VMEM with double buffering (2x on the input tiles).
    """
    bm = min(bm, m)
    bn = min(bn, n)
    x_tile = bm * k * 4
    w_tile = k * bn * 4
    o_tile = bm * bn * 4
    b_tile = bn * 4
    return 2 * (x_tile + w_tile + b_tile) + o_tile


def mxu_utilization_estimate(m, k, n, *, bm=BLOCK_M, bn=BLOCK_N):
    """Fraction of MXU issue slots doing useful work for the padded problem.

    The padded grid does ceil(M/bm)*ceil(N/bn)*bm*bn*K MACs; the useful work
    is M*N*K. Padding waste is the only inefficiency modeled (interpret mode
    gives no real timing).
    """
    gm = -(-m // bm) * bm
    gn = -(-n // bn) * bn
    return (m * n) / (gm * gn)
