//! Fig 15 (scaled): ResNet accuracy — SEQ vs HF-MP(2) vs HF-MP(8).
//! The paper trains ResNet-110-v1 for 150 epochs on CIFAR-10 and shows
//! every variant peaking at the same 92.5%; the claim being verified is
//! that model-parallel training *is* sequential training. This scaled run
//! uses ResNet-56-v1 (same architecture family, same code path) on the
//! synthetic set and asserts the three variants' loss histories are
//! IDENTICAL, then reports the shared accuracy trajectory.
//!
//!     cargo run --release --example fig15_resnet_accuracy [steps]

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let cfg = |s: Strategy, p: usize| {
        TrainConfig::new(zoo::resnet56_v1(), s)
            .partitions(p)
            .microbatch(8)
            .steps(steps)
            .lr(0.02)
            .seed(15)
            .eval_batches(8)
    };

    println!("fig15 (scaled): ResNet-56-v1, BS=32-equivalent, {steps} steps");
    println!("running SEQ...");
    let seq = fit(&cfg(Strategy::Sequential, 1))?;
    println!("running HF-MP(2)...");
    let mp2 = fit(&cfg(Strategy::Model, 2))?;
    println!("running HF-MP(8)...");
    let mp8 = fit(&cfg(Strategy::Model, 8))?;

    println!("\n step | SEQ loss | MP2 loss | MP8 loss | acc");
    for i in 0..steps {
        let (a, b, c) = (&seq.history[i], &mp2.history[i], &mp8.history[i]);
        if i % 5 == 0 || i + 1 == steps {
            println!(
                "{:>5} | {:>8.4} | {:>8.4} | {:>8.4} | {:.3}",
                i + 1, a.loss, b.loss, c.loss, a.accuracy
            );
        }
        assert_eq!(a.loss, b.loss, "MP(2) diverged from SEQ at step {}", i + 1);
        assert_eq!(a.loss, c.loss, "MP(8) diverged from SEQ at step {}", i + 1);
    }
    let e = seq.eval.unwrap();
    println!("\ntest: loss={:.4} acc={:.3} (chance = 0.100)", e.loss, e.accuracy);
    println!("OK: all variants produced identical training trajectories (paper Fig 15's 'all peak at the same accuracy', made exact)");
    Ok(())
}
