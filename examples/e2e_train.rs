//! End-to-end driver (DESIGN.md deliverable): train a ~100M-parameter
//! model (3072 -> 6x4096 MLP, 96.5M params) for a few hundred steps with
//! 4-way model parallelism on real PJRT compute, logging the loss curve.
//! All three layers compose here: Pallas matmul kernels inside the AOT
//! artifacts (L1/L2), the Rust coordinator moving activations/errors over
//! the hfmpi fabric (L3).
//!
//!     cargo run --release --example e2e_train [steps]
//!
//! The recorded run lives in EXPERIMENTS.md §End-to-end.

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let model = zoo::wide_mlp_100m();
    println!(
        "e2e: {} — {} params, {} weight layers, 4-way model parallel, {steps} steps",
        model.name,
        model.num_params(),
        model.num_weight_layers()
    );

    let cfg = TrainConfig::new(model, Strategy::Model)
        .partitions(4)
        .microbatch(16)
        .steps(steps)
        .lr(0.005)
        .seed(1234)
        .log_every(10)
        .eval_batches(8);
    let t0 = std::time::Instant::now();
    let res = fit(&cfg)?;

    println!("\nloss curve (every 10 steps):");
    for (i, m) in res.history.iter().enumerate() {
        if i % 10 == 0 || i + 1 == res.history.len() {
            println!("  step {:>4}: loss={:.4} acc={:.3}", i + 1, m.loss, m.accuracy);
        }
    }
    let first = res.history.first().unwrap().loss;
    let last = res.final_loss();
    println!(
        "\nloss {first:.4} -> {last:.4} | {:.1} img/s | wall {:.1}s",
        res.img_per_sec,
        t0.elapsed().as_secs_f64()
    );
    if let Some(e) = &res.eval {
        println!("held-out: loss={:.4} acc={:.3}", e.loss, e.accuracy);
    }
    anyhow::ensure!(last < first, "loss did not improve");
    Ok(())
}
