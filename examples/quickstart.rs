//! Quickstart: the paper's Listing 2 in Rust — train a Keras-style model
//! with a one-line strategy switch and zero model changes.
//!
//!     cargo run --release --example quickstart
//!
//! Requires artifacts: `make artifacts`.

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");

    // 1. Define (or pick) a model — no parallelism anywhere in it.
    let model = zoo::resnet20_v1();
    println!("{:?}", &model.name);
    println!(
        "model: {} weight layers, {} params",
        model.num_weight_layers(),
        model.num_params()
    );

    // 2. Train it hybrid-parallel: 2 model-partitions x 2 replicas.
    //    (the paper's four inputs: model, partitions, replicas, strategy)
    let cfg = TrainConfig::new(model, Strategy::Hybrid)
        .partitions(2)
        .replicas(2)
        .microbatch(8)
        .steps(12)
        .lr(0.02)
        .log_every(3)
        .eval_batches(4);
    let result = fit(&cfg)?;

    println!(
        "\nfinal loss {:.4}, eval acc {:.3}, {:.1} img/s across 4 ranks",
        result.final_loss(),
        result.eval.as_ref().map(|e| e.accuracy).unwrap_or(0.0),
        result.img_per_sec
    );

    // 3. Same model, different strategy — nothing else changes.
    let seq = fit(&TrainConfig::new(zoo::resnet20_v1(), Strategy::Sequential)
        .microbatch(8)
        .steps(3)
        .lr(0.02))?;
    println!("sequential sanity: loss {:.4}", seq.final_loss());
    Ok(())
}
