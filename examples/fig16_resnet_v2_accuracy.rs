//! Fig 16 (scaled): ResNet-v2 (pre-activation bottleneck) accuracy —
//! SEQ vs HF-MP(2). The paper trains ResNet-1001-v2 for 50 epochs on two
//! GPU nodes; this scaled run uses ResNet-29-v2 (same bottleneck block
//! structure, same projection shortcuts, same code path) and asserts the
//! MP(2) trajectory equals sequential while accuracy climbs.
//!
//!     cargo run --release --example fig16_resnet_v2_accuracy [steps]

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    let cfg = |s: Strategy, p: usize| {
        TrainConfig::new(zoo::resnet_v2(29, &[3, 32, 32], 10), s)
            .partitions(p)
            .microbatch(8)
            .steps(steps)
            .lr(0.02)
            .seed(16)
            .eval_batches(8)
    };

    println!("fig16 (scaled): ResNet-29-v2 bottleneck, {steps} steps");
    let seq = fit(&cfg(Strategy::Sequential, 1))?;
    let mp2 = fit(&cfg(Strategy::Model, 2))?;

    println!("\n step | SEQ loss | MP2 loss | acc");
    for i in 0..steps {
        let (a, b) = (&seq.history[i], &mp2.history[i]);
        if i % 5 == 0 || i + 1 == steps {
            println!("{:>5} | {:>8.4} | {:>8.4} | {:.3}", i + 1, a.loss, b.loss, a.accuracy);
        }
        assert_eq!(a.loss, b.loss, "MP(2) diverged from SEQ at step {}", i + 1);
    }
    let e = mp2.eval.unwrap();
    println!("\ntest: loss={:.4} acc={:.3}", e.loss, e.accuracy);
    anyhow::ensure!(
        seq.final_loss() < seq.history[0].loss,
        "loss did not improve"
    );
    println!("OK: v2 bottleneck MP(2) == SEQ, training converges");
    Ok(())
}
