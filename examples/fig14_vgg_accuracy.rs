//! Fig 14 (scaled): VGG-16 accuracy under model-parallel training.
//! The paper trains VGG-16 on CIFAR-10 with 8 partitions / BS=128 for 10
//! epochs; this scaled run trains the same VGG-16 architecture on the
//! synthetic CIFAR-like set with 4 partitions and asserts train metrics
//! improve — plus the stronger check the paper could not make: the
//! MP run's loss trajectory is **identical** to sequential.
//!
//!     cargo run --release --example fig14_vgg_accuracy [steps]

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;

fn main() -> anyhow::Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    let cfg = |s| {
        TrainConfig::new(zoo::vgg16(&[3, 32, 32], 10), s)
            .partitions(4)
            .microbatch(8)
            .num_microbatches(2) // BS 16 as 2 pipeline stages
            .steps(steps)
            .lr(0.003)
            .seed(14)
            .eval_batches(8)
    };

    println!("fig14 (scaled): VGG-16, MP(4), BS=16, {steps} steps");
    let mp = fit(&cfg(Strategy::Model).log_every(5))?;
    println!("sequential reference...");
    let seq = fit(&cfg(Strategy::Sequential))?;

    println!("\n step |  MP loss | SEQ loss |  MP acc");
    for (i, (a, b)) in mp.history.iter().zip(seq.history.iter()).enumerate() {
        if i % 5 == 0 || i + 1 == mp.history.len() {
            println!("{:>5} | {:>8.4} | {:>8.4} | {:>6.3}", i + 1, a.loss, b.loss, a.accuracy);
        }
        assert_eq!(a.loss, b.loss, "step {}: MP must track sequential exactly", i + 1);
    }
    let e = mp.eval.as_ref().unwrap();
    println!("\ntest: loss={:.4} acc={:.3} (chance = 0.100)", e.loss, e.accuracy);
    let first = mp.history[0].loss;
    anyhow::ensure!(mp.final_loss() < first, "train loss did not improve");
    println!("OK: MP(4) training improved and tracked sequential bit-for-bit");
    Ok(())
}
