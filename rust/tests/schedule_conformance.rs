//! Schedule-conformance harness: for every [`ScheduleKind`], machine-check
//! the compiled programs on random skip-topology graphs x microbatch
//! counts, and the numerics end to end through the native executor.
//!
//! (a) **Deadlock-freedom** — the program completes under the semantics
//!     its generator documents: rendezvous (unbuffered synchronous) sends
//!     for GPipe, buffered sends for the 1F1B family (what the hfmpi
//!     fabric implements), with full `(cross-rank edge, microbatch)`
//!     coverage.
//! (b) **Residency** — per-rank peak stash residency never exceeds the
//!     documented bound: `m` for GPipe, `min(P - rank, m)` for 1F1B and
//!     ZB-H1, `min(2P, m)` for interleaved.
//! (c) **Pairing** — every send/recv is exactly-once, faces the right
//!     peer, never targets its own rank, and both endpoints of each
//!     `(edge, class)` channel see the microbatches in the same order.
//! (d) **Numerics** — model-parallel training under each schedule is
//!     bitwise equal (loss history and every parameter) to the sequential
//!     run under the same schedule.
//!
//! Plus the golden-snapshot regression of the 1F1B program listing
//! (`rust/tests/golden/one_f1b_mlp_4x8.txt`).

use hyparflow::api::{fit, FitResult, Strategy, TrainConfig};
use hyparflow::graph::{zoo, ModelGraph};
use hyparflow::partition::Partitioning;
use hyparflow::rng::Rng;
use hyparflow::schedule::{Instr, Program, ScheduleKind, SendSemantics};

fn all_kinds() -> [ScheduleKind; 4] {
    [
        ScheduleKind::GPipe,
        ScheduleKind::OneF1B,
        ScheduleKind::Interleaved1F1B { v: 2 },
        ScheduleKind::ZbH1,
    ]
}

/// Random conv/skip graph in the ResNet family (same generator family as
/// rust/tests/proptests.rs): chains of conv-bn-relu with random Add skip
/// edges back to earlier same-shape nodes. Always >= 11 nodes.
fn random_skip_graph(rng: &mut Rng) -> ModelGraph {
    let mut g = ModelGraph::new("fuzz", &[3, 8, 8]);
    let x = g.input();
    let mut cur = g.conv3x3(x, 4, 1);
    let mut checkpoints = vec![cur];
    let blocks = 2 + rng.below(6);
    for _ in 0..blocks {
        let c = g.conv3x3(cur, 4, 1);
        let b = g.batchnorm(c);
        let r = g.relu(b);
        cur = r;
        if rng.below(2) == 0 && !checkpoints.is_empty() {
            let src = checkpoints[rng.below(checkpoints.len())];
            cur = g.add(cur, src);
        }
        checkpoints.push(cur);
    }
    let p = g.gap(cur);
    let d = g.dense(p, 3);
    g.loss(d);
    g
}

/// Random LPP vector: contiguous, non-empty, sums to n.
fn random_lpp(rng: &mut Rng, n: usize, parts: usize) -> Vec<usize> {
    let mut cuts: Vec<usize> = (0..parts - 1).map(|_| 1 + rng.below(n - 1)).collect();
    cuts.sort();
    cuts.dedup();
    while cuts.len() < parts - 1 {
        let c = 1 + rng.below(n - 1);
        if !cuts.contains(&c) {
            cuts.push(c);
            cuts.sort();
        }
    }
    let mut lpp = vec![];
    let mut prev = 0;
    for c in cuts {
        lpp.push(c - prev);
        prev = c;
    }
    lpp.push(n - prev);
    lpp
}

/// Edges that cross *ranks* (stage-level edges between two chunks of the
/// same rank are elided by the generators and carry no messages).
fn cross_rank_edges(pt: &Partitioning, ranks: usize) -> usize {
    pt.edges.iter().filter(|e| e.src_part % ranks != e.dst_part % ranks).count()
}

#[test]
fn programs_complete_under_documented_semantics_on_random_topologies() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 9000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(2); // 2..=3
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, n, stages);
            let pt = Partitioning::from_lpp(&g, &lpp)
                .unwrap_or_else(|e| panic!("seed {seed}: partition {lpp:?}: {e}"));
            let sem = match kind {
                ScheduleKind::GPipe => SendSemantics::Rendezvous,
                _ => SendSemantics::Buffered,
            };
            for m in [1usize, 2, 6] {
                let prog = Program::compile(&g, &pt, m, kind);
                assert_eq!(prog.num_partitions, ranks, "{}", kind.label());
                assert_eq!(prog.num_stages, stages, "{}", kind.label());
                let steps = prog.check(sem).unwrap_or_else(|stuck| {
                    panic!(
                        "seed {seed} {} R={ranks} m={m}: deadlock, stuck ranks \
                         {stuck:?}, lpp={lpp:?}",
                        kind.label()
                    )
                });
                assert_eq!(
                    steps,
                    cross_rank_edges(&pt, ranks) * 2 * m,
                    "seed {seed} {} m={m}: (edge, mb) coverage",
                    kind.label()
                );
                prog.verify_message_pairing().unwrap_or_else(|e| {
                    panic!("seed {seed} {} m={m}: pairing: {e}", kind.label())
                });
            }
        }
    }
}

#[test]
fn residency_stays_within_documented_bounds() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed + 11_000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(3); // 2..=4
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, n, stages);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            for m in [1usize, 3, 9] {
                let prog = Program::compile(&g, &pt, m, kind);
                for r in 0..ranks {
                    let peak = prog.peak_resident_microbatches(r);
                    let bound = match kind {
                        ScheduleKind::GPipe => m,
                        ScheduleKind::OneF1B | ScheduleKind::ZbH1 => (ranks - r).min(m),
                        ScheduleKind::Interleaved1F1B { .. } => (2 * ranks).min(m),
                    };
                    assert!(
                        peak <= bound,
                        "seed {seed} {} R={ranks} m={m} rank {r}: resident {peak} \
                         exceeds documented bound {bound} (lpp {lpp:?})",
                        kind.label()
                    );
                }
                if kind == ScheduleKind::GPipe {
                    // Fill/drain keeps every microbatch stashed: the bound
                    // is attained, not just respected.
                    assert_eq!(prog.max_peak_resident_microbatches(), m);
                }
            }
        }
    }
}

fn mlp_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), strategy)
        .microbatch(4)
        .num_microbatches(4)
        .steps(3)
        .lr(0.05)
        .seed(21)
}

fn resnet_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::resnet20_v1(), strategy)
        .microbatch(4)
        .num_microbatches(3)
        .steps(2)
        .lr(0.01)
        .seed(11)
}

fn loss_history(r: &FitResult) -> Vec<f32> {
    r.history.iter().map(|m| m.loss).collect()
}

fn max_param_diff(a: &FitResult, b: &FitResult) -> f32 {
    assert_eq!(a.params.len(), b.params.len(), "param sets differ");
    let mut worst = 0.0f32;
    for ((ka, ta), (kb, tb)) in a.params.iter().zip(b.params.iter()) {
        assert_eq!(ka, kb, "param key order mismatch");
        worst = worst.max(ta.max_abs_diff(tb));
    }
    worst
}

#[test]
fn training_is_bitwise_equal_to_sequential_mlp() {
    // (d) on the MLP: every schedule's gradient-accumulation order is
    // rank-invariant by construction, so the model-parallel run must be
    // bitwise equal to the sequential run under the same schedule.
    for kind in all_kinds() {
        let seq = fit(&mlp_cfg(Strategy::Sequential).schedule(kind)).unwrap();
        // Interleaved v=2 needs 2P stages out of 6 nodes, capping P at 3.
        let ps: &[usize] = if kind.virtual_stages() > 1 { &[2, 3] } else { &[2, 3, 4] };
        for &p in ps {
            let mp = fit(&mlp_cfg(Strategy::Model).partitions(p).schedule(kind)).unwrap();
            assert_eq!(
                loss_history(&seq),
                loss_history(&mp),
                "{} P={p}: loss history diverged",
                kind.label()
            );
            let d = max_param_diff(&seq, &mp);
            assert_eq!(d, 0.0, "{} P={p}: max param diff {d}", kind.label());
        }
    }
}

#[test]
fn training_is_bitwise_equal_to_sequential_resnet() {
    // (d) with conv + BN + skip connections crossing rank boundaries.
    for kind in all_kinds() {
        let seq = fit(&resnet_cfg(Strategy::Sequential).schedule(kind)).unwrap();
        let p = if kind.virtual_stages() > 1 { 2 } else { 4 };
        let mp = fit(&resnet_cfg(Strategy::Model).partitions(p).schedule(kind)).unwrap();
        assert_eq!(
            loss_history(&seq),
            loss_history(&mp),
            "{} P={p}: loss history diverged",
            kind.label()
        );
        let d = max_param_diff(&seq, &mp);
        assert_eq!(d, 0.0, "{} P={p}: max param diff {d}", kind.label());
    }
}

// ---------------------------------------------------------------------------
// Golden snapshot: the 1F1B program listing for a 4-rank / 8-microbatch MLP.
// ---------------------------------------------------------------------------

fn render_instr(i: &Instr) -> String {
    match *i {
        Instr::FwdCompute { node, stage, mb } => format!("F n{node} s{stage} mb{mb}"),
        Instr::BwdCompute { node, stage, mb } => format!("B n{node} s{stage} mb{mb}"),
        Instr::BwdInput { node, stage, mb } => format!("BI n{node} s{stage} mb{mb}"),
        Instr::BwdWeight { node, stage, mb } => format!("BW n{node} s{stage} mb{mb}"),
        Instr::SendActivation { edge, peer, mb } => format!("SA e{edge}->r{peer} mb{mb}"),
        Instr::RecvActivation { edge, peer, mb } => format!("RA e{edge}<-r{peer} mb{mb}"),
        Instr::SendError { edge, peer, mb } => format!("SE e{edge}->r{peer} mb{mb}"),
        Instr::RecvError { edge, peer, mb } => format!("RE e{edge}<-r{peer} mb{mb}"),
        Instr::DropStash { mb } => format!("DROP mb{mb}"),
        Instr::AllreduceGrads => "ALLREDUCE".to_string(),
        Instr::OptStep => "OPT".to_string(),
    }
}

fn render_program(prog: &Program) -> String {
    let mut out = String::new();
    out.push_str("# one_f1b program listing: mlp(8, [8, 8, 8], 4), lpp [2, 2, 1, 1], m=8\n");
    out.push_str(
        "# Golden snapshot; regenerate with \
         HF_BLESS_GOLDEN=1 cargo test --test schedule_conformance\n",
    );
    for rank in 0..prog.num_partitions {
        out.push_str(&format!("rank {rank}\n"));
        for i in prog.rank(rank) {
            out.push_str(&format!("  {}\n", render_instr(i)));
        }
    }
    out
}

#[test]
fn one_f1b_golden_program_listing() {
    // Any change to the 1F1B generator's op order shows up as a diff of
    // this listing — the scheduling analogue of a model-output snapshot.
    let g = zoo::mlp(8, &[8, 8, 8], 4);
    let pt = Partitioning::from_lpp(&g, &[2, 2, 1, 1]).unwrap();
    let prog = Program::compile(&g, &pt, 8, ScheduleKind::OneF1B);
    let got = render_program(&prog);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/one_f1b_mlp_4x8.txt");
    if std::env::var("HF_BLESS_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("missing golden file {path}: {e}"));
    assert_eq!(
        got, want,
        "one_f1b program listing changed; if intended, bless with \
         HF_BLESS_GOLDEN=1 cargo test --test schedule_conformance"
    );
}
