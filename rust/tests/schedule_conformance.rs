//! Schedule-conformance harness: for every [`ScheduleKind`], machine-check
//! the compiled programs on random skip-topology graphs x microbatch
//! counts x **transport semantics**, and the numerics end to end through
//! the native executor.
//!
//! (a) **Deadlock-freedom** — blocking programs complete under the
//!     semantics their generator documents: rendezvous (unbuffered
//!     synchronous) sends for GPipe, buffered sends for the 1F1B family
//!     (what the hfmpi fabric implements). *Eager* programs
//!     (`SendMode::Eager`, MPI_Isend-style `PostSend*`/`WaitSend` pairs)
//!     must complete under BOTH semantics for every kind — full
//!     `(cross-rank edge, microbatch)` coverage either way. The blocking
//!     1F1B rendezvous deadlock is pinned as a regression canary: if it
//!     ever stops deadlocking, the generator changed.
//! (b) **Residency** — per-rank peak stash residency never exceeds the
//!     documented bound: `m` for GPipe, `min(P - rank, m)` for 1F1B and
//!     ZB-H1, `min(2P, m)` for interleaved. Eager programs additionally
//!     keep in-flight send buffers within `channels x resident
//!     microbatches` (waits sit at the end of each payload's live
//!     interval).
//! (c) **Pairing** — every send/recv is exactly-once, faces the right
//!     peer, never targets its own rank, and both endpoints of each
//!     `(edge, class)` channel see the microbatches in the same order;
//!     eager programs additionally pair every post with exactly one
//!     later wait.
//! (d) **Numerics** — model-parallel training under each schedule is
//!     bitwise equal (loss history and every parameter) to the sequential
//!     run under the same schedule, with blocking *and* eager sends (the
//!     rewrite moves completion points, never payloads).
//!
//! Plus golden-snapshot regressions of the 1F1B (blocking + eager),
//! interleaved-1F1B and ZB-H1 program listings under `rust/tests/golden/`.

use hyparflow::api::{fit, FitResult, Strategy, TrainConfig};
use hyparflow::graph::{zoo, ModelGraph};
use hyparflow::hfmpi::Transport;
use hyparflow::partition::Partitioning;
use hyparflow::rng::Rng;
use hyparflow::schedule::{Instr, Program, ScheduleKind, SendMode, SendSemantics};

fn all_kinds() -> [ScheduleKind; 4] {
    [
        ScheduleKind::GPipe,
        ScheduleKind::OneF1B,
        ScheduleKind::Interleaved1F1B { v: 2 },
        ScheduleKind::ZbH1,
    ]
}

/// Random conv/skip graph in the ResNet family (same generator family as
/// rust/tests/proptests.rs): chains of conv-bn-relu with random Add skip
/// edges back to earlier same-shape nodes. Always >= 11 nodes.
fn random_skip_graph(rng: &mut Rng) -> ModelGraph {
    let mut g = ModelGraph::new("fuzz", &[3, 8, 8]);
    let x = g.input();
    let mut cur = g.conv3x3(x, 4, 1);
    let mut checkpoints = vec![cur];
    let blocks = 2 + rng.below(6);
    for _ in 0..blocks {
        let c = g.conv3x3(cur, 4, 1);
        let b = g.batchnorm(c);
        let r = g.relu(b);
        cur = r;
        if rng.below(2) == 0 && !checkpoints.is_empty() {
            let src = checkpoints[rng.below(checkpoints.len())];
            cur = g.add(cur, src);
        }
        checkpoints.push(cur);
    }
    let p = g.gap(cur);
    let d = g.dense(p, 3);
    g.loss(d);
    g
}

/// Random LPP vector: contiguous, non-empty, sums to n.
fn random_lpp(rng: &mut Rng, n: usize, parts: usize) -> Vec<usize> {
    let mut cuts: Vec<usize> = (0..parts - 1).map(|_| 1 + rng.below(n - 1)).collect();
    cuts.sort();
    cuts.dedup();
    while cuts.len() < parts - 1 {
        let c = 1 + rng.below(n - 1);
        if !cuts.contains(&c) {
            cuts.push(c);
            cuts.sort();
        }
    }
    let mut lpp = vec![];
    let mut prev = 0;
    for c in cuts {
        lpp.push(c - prev);
        prev = c;
    }
    lpp.push(n - prev);
    lpp
}

/// Edges that cross *ranks* (stage-level edges between two chunks of the
/// same rank are elided by the generators and carry no messages).
fn cross_rank_edges(pt: &Partitioning, ranks: usize) -> usize {
    pt.edges.iter().filter(|e| e.src_part % ranks != e.dst_part % ranks).count()
}

#[test]
fn programs_complete_under_documented_semantics_on_random_topologies() {
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 9000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(2); // 2..=3
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, n, stages);
            let pt = Partitioning::from_lpp(&g, &lpp)
                .unwrap_or_else(|e| panic!("seed {seed}: partition {lpp:?}: {e}"));
            let sem = match kind {
                ScheduleKind::GPipe => SendSemantics::Rendezvous,
                _ => SendSemantics::Buffered,
            };
            for m in [1usize, 2, 6] {
                let prog = Program::compile(&g, &pt, m, kind);
                assert_eq!(prog.num_partitions, ranks, "{}", kind.label());
                assert_eq!(prog.num_stages, stages, "{}", kind.label());
                let steps = prog.check(sem).unwrap_or_else(|stuck| {
                    panic!(
                        "seed {seed} {} R={ranks} m={m}: deadlock, stuck ranks \
                         {stuck:?}, lpp={lpp:?}",
                        kind.label()
                    )
                });
                assert_eq!(
                    steps,
                    cross_rank_edges(&pt, ranks) * 2 * m,
                    "seed {seed} {} m={m}: (edge, mb) coverage",
                    kind.label()
                );
                prog.verify_message_pairing().unwrap_or_else(|e| {
                    panic!("seed {seed} {} m={m}: pairing: {e}", kind.label())
                });
            }
        }
    }
}

#[test]
fn eager_programs_complete_under_both_semantics_on_random_topologies() {
    // The tentpole property: the eager rewrite makes EVERY kind
    // deadlock-free under rendezvous semantics (not just buffered), with
    // the same full (edge, mb) message coverage, on random skip
    // topologies.
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 13_000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(2); // 2..=3
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, n, stages);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            for m in [1usize, 2, 6] {
                let prog = Program::compile_with(&g, &pt, m, kind, SendMode::Eager);
                assert_eq!(prog.send_mode, SendMode::Eager);
                let want = cross_rank_edges(&pt, ranks) * 2 * m;
                for sem in [SendSemantics::Rendezvous, SendSemantics::Buffered] {
                    let steps = prog.check(sem).unwrap_or_else(|stuck| {
                        panic!(
                            "seed {seed} {} R={ranks} m={m} {sem:?}: deadlock, \
                             stuck ranks {stuck:?}, lpp={lpp:?}",
                            kind.label()
                        )
                    });
                    assert_eq!(
                        steps,
                        want,
                        "seed {seed} {} m={m} {sem:?}: (edge, mb) coverage",
                        kind.label()
                    );
                }
                prog.verify_message_pairing().unwrap();
                prog.verify_eager_pairing().unwrap_or_else(|e| {
                    panic!("seed {seed} {} m={m}: eager pairing: {e}", kind.label())
                });
            }
        }
    }
}

#[test]
fn blocking_one_f1b_deadlocks_under_rendezvous_and_eager_fixes_it() {
    // Regression canary for the documented facing-send deadlock. On a
    // chain at m >= 2 the blocking 1F1B steady state puts two sends head
    // to head (stage i's forward send of microbatch k+1 against stage
    // i+1's error send of microbatch k), so the rendezvous checker must
    // reject it — if it ever starts passing, the generator changed and
    // the module docs are stale. The eager rewrite of the *same* program
    // must pass with identical message coverage.
    let g = zoo::mlp(8, &[8, 8, 8], 4);
    for (lpp, ranks) in [(vec![2usize, 2, 2], 3usize), (vec![2, 2, 1, 1], 4)] {
        let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
        for m in [2usize, 6] {
            let prog = Program::compile(&g, &pt, m, ScheduleKind::OneF1B);
            assert!(
                prog.check(SendSemantics::Rendezvous).is_err(),
                "P={ranks} m={m}: blocking 1F1B stopped deadlocking under \
                 rendezvous — generator changed?"
            );
            let buffered = prog.check(SendSemantics::Buffered).unwrap();
            let eager = prog.into_eager();
            let rdv = eager.check(SendSemantics::Rendezvous).unwrap_or_else(|stuck| {
                panic!("P={ranks} m={m}: eager 1F1B stuck ranks {stuck:?}")
            });
            assert_eq!(rdv, buffered, "P={ranks} m={m}: same message coverage");
        }
        // m=1 is warmup-only (GPipe-shaped) and rendezvous-safe even
        // blocking — the deadlock needs a steady state to exist.
        let prog = Program::compile(&g, &pt, 1, ScheduleKind::OneF1B);
        assert!(prog.check(SendSemantics::Rendezvous).is_ok(), "P={ranks} m=1");
    }
}

#[test]
fn eager_in_flight_sends_stay_within_residency_bounds() {
    // (b) for send buffers: waits sit before the owning microbatch's
    // DropStash, so a rank can never hold more in-flight sends than
    // (distinct send channels) x (resident microbatches). This is the
    // bound that sizes the CommEngine's in-flight budget.
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed + 19_000);
        let g = random_skip_graph(&mut rng);
        let ranks = 2 + rng.below(3); // 2..=4
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, g.num_nodes(), stages);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            for m in [1usize, 3, 9] {
                let prog = Program::compile_with(&g, &pt, m, kind, SendMode::Eager);
                for r in 0..ranks {
                    let channels: std::collections::HashSet<(usize, u8)> = prog
                        .rank(r)
                        .iter()
                        .filter_map(|i| match *i {
                            Instr::PostSendActivation { edge, .. } => Some((edge, 0)),
                            Instr::PostSendError { edge, .. } => Some((edge, 1)),
                            _ => None,
                        })
                        .collect();
                    let bound =
                        channels.len() * prog.peak_resident_microbatches(r).max(1);
                    let got = prog.peak_in_flight_sends(r);
                    assert!(
                        got <= bound,
                        "seed {seed} {} R={ranks} m={m} rank {r}: {got} in-flight \
                         sends exceed {} channels x residency bound {bound}",
                        kind.label(),
                        channels.len()
                    );
                }
                // And the worldwide peak respects the tag-space pigeonhole
                // budget the CommEngine enforces at construction.
                assert!(
                    prog.max_in_flight_sends() <= 2 * pt.edges.len() * m.max(1),
                    "seed {seed} {} m={m}: tag budget",
                    kind.label()
                );
            }
        }
    }
}

#[test]
fn residency_stays_within_documented_bounds() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed + 11_000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(3); // 2..=4
        for kind in all_kinds() {
            let stages = ranks * kind.virtual_stages();
            let lpp = random_lpp(&mut rng, n, stages);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            for m in [1usize, 3, 9] {
                let prog = Program::compile(&g, &pt, m, kind);
                for r in 0..ranks {
                    let peak = prog.peak_resident_microbatches(r);
                    let bound = match kind {
                        ScheduleKind::GPipe => m,
                        ScheduleKind::OneF1B | ScheduleKind::ZbH1 => (ranks - r).min(m),
                        ScheduleKind::Interleaved1F1B { .. } => (2 * ranks).min(m),
                    };
                    assert!(
                        peak <= bound,
                        "seed {seed} {} R={ranks} m={m} rank {r}: resident {peak} \
                         exceeds documented bound {bound} (lpp {lpp:?})",
                        kind.label()
                    );
                }
                if kind == ScheduleKind::GPipe {
                    // Fill/drain keeps every microbatch stashed: the bound
                    // is attained, not just respected.
                    assert_eq!(prog.max_peak_resident_microbatches(), m);
                }
            }
        }
    }
}

fn mlp_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), strategy)
        .microbatch(4)
        .num_microbatches(4)
        .steps(3)
        .lr(0.05)
        .seed(21)
}

fn resnet_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::resnet20_v1(), strategy)
        .microbatch(4)
        .num_microbatches(3)
        .steps(2)
        .lr(0.01)
        .seed(11)
}

fn loss_history(r: &FitResult) -> Vec<f32> {
    r.history.iter().map(|m| m.loss).collect()
}

fn max_param_diff(a: &FitResult, b: &FitResult) -> f32 {
    assert_eq!(a.params.len(), b.params.len(), "param sets differ");
    let mut worst = 0.0f32;
    for ((ka, ta), (kb, tb)) in a.params.iter().zip(b.params.iter()) {
        assert_eq!(ka, kb, "param key order mismatch");
        worst = worst.max(ta.max_abs_diff(tb));
    }
    worst
}

#[test]
fn training_is_bitwise_equal_to_sequential_mlp() {
    // (d) on the MLP: every schedule's gradient-accumulation order is
    // rank-invariant by construction, so the model-parallel run must be
    // bitwise equal to the sequential run under the same schedule.
    for kind in all_kinds() {
        let seq = fit(&mlp_cfg(Strategy::Sequential).schedule(kind)).unwrap();
        // Interleaved v=2 needs 2P stages out of 6 nodes, capping P at 3.
        let ps: &[usize] = if kind.virtual_stages() > 1 { &[2, 3] } else { &[2, 3, 4] };
        for &p in ps {
            let mp = fit(&mlp_cfg(Strategy::Model).partitions(p).schedule(kind)).unwrap();
            assert_eq!(
                loss_history(&seq),
                loss_history(&mp),
                "{} P={p}: loss history diverged",
                kind.label()
            );
            let d = max_param_diff(&seq, &mp);
            assert_eq!(d, 0.0, "{} P={p}: max param diff {d}", kind.label());
        }
    }
}

#[test]
fn training_is_bitwise_equal_to_sequential_resnet() {
    // (d) with conv + BN + skip connections crossing rank boundaries.
    for kind in all_kinds() {
        let seq = fit(&resnet_cfg(Strategy::Sequential).schedule(kind)).unwrap();
        let p = if kind.virtual_stages() > 1 { 2 } else { 4 };
        let mp = fit(&resnet_cfg(Strategy::Model).partitions(p).schedule(kind)).unwrap();
        assert_eq!(
            loss_history(&seq),
            loss_history(&mp),
            "{} P={p}: loss history diverged",
            kind.label()
        );
        let d = max_param_diff(&seq, &mp);
        assert_eq!(d, 0.0, "{} P={p}: max param diff {d}", kind.label());
    }
}

#[test]
fn eager_sends_train_bitwise_equal_to_blocking_mlp() {
    // (d) on the transport axis: the eager rewrite moves send *completion
    // points*, never payloads or arithmetic order, so training with
    // eager sends must be bitwise identical to blocking sends — and both
    // to the sequential run — for every kind.
    let seq = fit(&mlp_cfg(Strategy::Sequential)).unwrap();
    for kind in all_kinds() {
        let p = if kind.virtual_stages() > 1 { 3 } else { 4 };
        let base = mlp_cfg(Strategy::Model).partitions(p).schedule(kind);
        // The blocking legs are pinned to the buffered fabric: under
        // `HF_TRANSPORT=rendezvous` (a CI matrix row) blocking 1F1B-family
        // programs deadlock by design — that case is the live canary
        // `blocking_one_f1b_deadlocks_on_the_live_rendezvous_fabric`.
        let blocking =
            fit(&base.clone().eager_sends(false).transport(Transport::Buffered)).unwrap();
        let eager = fit(&base.eager_sends(true)).unwrap();
        assert_eq!(
            loss_history(&blocking),
            loss_history(&eager),
            "{} P={p}: eager vs blocking loss history",
            kind.label()
        );
        let d = max_param_diff(&blocking, &eager);
        assert_eq!(d, 0.0, "{} P={p}: eager vs blocking params", kind.label());
        assert_eq!(loss_history(&seq), loss_history(&eager), "{} vs sequential", kind.label());
    }
}

#[test]
fn eager_sends_train_bitwise_equal_to_blocking_resnet() {
    // Same property through conv + BN + cross-rank skip edges, where
    // eager error posts pin real gradient payloads in flight.
    let kind = ScheduleKind::OneF1B;
    let base = resnet_cfg(Strategy::Model).partitions(4).schedule(kind);
    // Blocking leg pinned to buffered (see the mlp variant above).
    let blocking =
        fit(&base.clone().eager_sends(false).transport(Transport::Buffered)).unwrap();
    let eager = fit(&base.eager_sends(true)).unwrap();
    assert_eq!(loss_history(&blocking), loss_history(&eager), "loss history");
    assert_eq!(max_param_diff(&blocking, &eager), 0.0, "params");
}

#[test]
fn eager_one_f1b_on_live_rendezvous_fabric_is_bitwise_identical_to_buffered() {
    // (d) on the *live fabric's* transport axis: rendezvous moves send
    // completion points to the matching receive — payloads, per-key
    // ordering and arithmetic are untouched — so an eager program that
    // completes on both transports trains bitwise identically on both.
    let base = mlp_cfg(Strategy::Model)
        .partitions(4)
        .schedule(ScheduleKind::OneF1B)
        .eager_sends(true);
    let buffered = fit(&base.clone().transport(Transport::Buffered)).unwrap();
    let rendezvous = fit(&base.transport(Transport::Rendezvous)).unwrap();
    assert_eq!(
        loss_history(&buffered),
        loss_history(&rendezvous),
        "buffered vs rendezvous loss history"
    );
    let d = max_param_diff(&buffered, &rendezvous);
    assert_eq!(d, 0.0, "buffered vs rendezvous: max param diff {d}");
}

#[test]
#[should_panic(expected = "deadlock watchdog")]
fn blocking_one_f1b_deadlocks_on_the_live_rendezvous_fabric() {
    // The checker-level canary above
    // (`blocking_one_f1b_deadlocks_under_rendezvous_and_eager_fixes_it`)
    // reproduced for real: on the rendezvous fabric the blocking 1F1B
    // steady state puts two sends head to head and the fixed watchdog —
    // not a hung test runner — reports the deadlock.
    let cfg = mlp_cfg(Strategy::Model)
        .partitions(3)
        .lpp(vec![2, 2, 2])
        .schedule(ScheduleKind::OneF1B)
        .eager_sends(false)
        .transport(Transport::Rendezvous)
        .comm_timeout(std::time::Duration::from_secs(2));
    let _ = fit(&cfg);
}

// ---------------------------------------------------------------------------
// Golden snapshots: program listings for a 4-stage MLP under 1F1B
// (blocking + eager), interleaved-1F1B and ZB-H1. Any change to a
// generator's op order — or to the eager rewrite's Post/Wait placement —
// shows up as a diff of these listings.
// ---------------------------------------------------------------------------

fn render_instr(i: &Instr) -> String {
    match *i {
        Instr::FwdCompute { node, stage, mb } => format!("F n{node} s{stage} mb{mb}"),
        Instr::BwdCompute { node, stage, mb } => format!("B n{node} s{stage} mb{mb}"),
        Instr::BwdInput { node, stage, mb } => format!("BI n{node} s{stage} mb{mb}"),
        Instr::BwdWeight { node, stage, mb } => format!("BW n{node} s{stage} mb{mb}"),
        Instr::SendActivation { edge, peer, mb } => format!("SA e{edge}->r{peer} mb{mb}"),
        Instr::RecvActivation { edge, peer, mb } => format!("RA e{edge}<-r{peer} mb{mb}"),
        Instr::SendError { edge, peer, mb } => format!("SE e{edge}->r{peer} mb{mb}"),
        Instr::RecvError { edge, peer, mb } => format!("RE e{edge}<-r{peer} mb{mb}"),
        Instr::PostSendActivation { edge, peer, mb, handle } => {
            format!("PSA e{edge}->r{peer} mb{mb} h{handle}")
        }
        Instr::PostSendError { edge, peer, mb, handle } => {
            format!("PSE e{edge}->r{peer} mb{mb} h{handle}")
        }
        Instr::WaitSend { handle } => format!("WS h{handle}"),
        Instr::DropStash { mb } => format!("DROP mb{mb}"),
        Instr::AllreduceGrads => "ALLREDUCE".to_string(),
        Instr::OptStep => "OPT".to_string(),
    }
}

fn render_program(prog: &Program, header: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("# {header}\n"));
    out.push_str(
        "# Golden snapshot; regenerate with \
         HF_BLESS_GOLDEN=1 cargo test --test schedule_conformance\n",
    );
    for rank in 0..prog.num_partitions {
        out.push_str(&format!("rank {rank}\n"));
        for i in prog.rank(rank) {
            out.push_str(&format!("  {}\n", render_instr(i)));
        }
    }
    out
}

fn golden_check(prog: &Program, header: &str, path: &str) {
    let got = render_program(prog, header);
    if std::env::var("HF_BLESS_GOLDEN").is_ok() {
        std::fs::write(path, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("missing golden file {path}: {e}"));
    assert_eq!(
        got, want,
        "program listing diverged from {path}; if intended, bless with \
         HF_BLESS_GOLDEN=1 cargo test --test schedule_conformance"
    );
}

fn golden_mlp() -> ModelGraph {
    zoo::mlp(8, &[8, 8, 8], 4)
}

#[test]
fn one_f1b_golden_program_listing() {
    let g = golden_mlp();
    let pt = Partitioning::from_lpp(&g, &[2, 2, 1, 1]).unwrap();
    let prog = Program::compile(&g, &pt, 8, ScheduleKind::OneF1B);
    golden_check(
        &prog,
        "one_f1b program listing: mlp(8, [8, 8, 8], 4), lpp [2, 2, 1, 1], m=8",
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/one_f1b_mlp_4x8.txt"),
    );
}

#[test]
fn eager_one_f1b_golden_program_listing() {
    // The eager rewrite of the listing above: every SA/SE becomes a
    // PSA/PSE with a fresh per-rank handle, and the paired WS sits
    // immediately before the owning microbatch's DROP.
    let g = golden_mlp();
    let pt = Partitioning::from_lpp(&g, &[2, 2, 1, 1]).unwrap();
    let prog = Program::compile_with(&g, &pt, 8, ScheduleKind::OneF1B, SendMode::Eager);
    golden_check(
        &prog,
        "one_f1b eager program listing: mlp(8, [8, 8, 8], 4), lpp [2, 2, 1, 1], \
         m=8, SendMode::Eager",
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/eager_one_f1b_mlp_4x8.txt"),
    );
}

#[test]
fn interleaved_1f1b_golden_program_listing() {
    // 2 ranks x v=2 chunks over the same 4-stage cut: rank 0 owns stages
    // {0, 2}, rank 1 owns {1, 3}.
    let g = golden_mlp();
    let pt = Partitioning::from_lpp(&g, &[2, 2, 1, 1]).unwrap();
    let prog = Program::compile(&g, &pt, 4, ScheduleKind::Interleaved1F1B { v: 2 });
    golden_check(
        &prog,
        "interleaved_1f1b:v=2 program listing: mlp(8, [8, 8, 8], 4), \
         lpp [2, 2, 1, 1], m=4",
        concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/rust/tests/golden/interleaved_1f1b_v2_mlp_2x4.txt"
        ),
    );
}

#[test]
fn zb_h1_golden_program_listing() {
    // The split-backward schedule: BI on the critical path, each BW
    // deferred by the rank's warmup depth into the drain bubble.
    let g = golden_mlp();
    let pt = Partitioning::from_lpp(&g, &[2, 2, 1, 1]).unwrap();
    let prog = Program::compile(&g, &pt, 8, ScheduleKind::ZbH1);
    golden_check(
        &prog,
        "zb_h1 program listing: mlp(8, [8, 8, 8], 4), lpp [2, 2, 1, 1], m=8",
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/zb_h1_mlp_4x8.txt"),
    );
}
