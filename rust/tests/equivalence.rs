//! The machine check of the paper's §6.1 guarantee: the Model Generator
//! "follows sequential semantics for the distributed model-parallel version
//! it creates" — same hyperparameters, same updates, no accuracy impact.
//!
//! Model-parallel runs must produce **identical** weights to sequential
//! (partitioning moves ops across ranks but never changes the math; sends
//! copy exact floats). Data-parallel/hybrid averaging over equal shards is
//! equal to the big-batch mean up to float reassociation, so those compare
//! with a tolerance.

use hyparflow::api::{fit, FitResult, Strategy, TrainConfig};
use hyparflow::graph::zoo;
use hyparflow::schedule::ScheduleKind;

fn mlp_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), strategy)
        .microbatch(4)
        .steps(6)
        .lr(0.05)
        .seed(7)
}

fn resnet_cfg(strategy: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::resnet20_v1(), strategy)
        .microbatch(4)
        .steps(2)
        .lr(0.01)
        .seed(11)
}

fn max_param_diff(a: &FitResult, b: &FitResult) -> f32 {
    assert_eq!(a.params.len(), b.params.len(), "param sets differ");
    let mut worst = 0.0f32;
    for ((ka, ta), (kb, tb)) in a.params.iter().zip(b.params.iter()) {
        assert_eq!(ka, kb, "param key order mismatch");
        worst = worst.max(ta.max_abs_diff(tb));
    }
    worst
}

fn loss_history(r: &FitResult) -> Vec<f32> {
    r.history.iter().map(|m| m.loss).collect()
}

#[test]
fn mlp_model_parallel_matches_sequential_exactly() {
    let seq = fit(&mlp_cfg(Strategy::Sequential)).unwrap();
    for p in [2, 3, 4] {
        let mp = fit(&mlp_cfg(Strategy::Model).partitions(p)).unwrap();
        assert_eq!(
            loss_history(&seq),
            loss_history(&mp),
            "loss history diverged at P={p}"
        );
        let d = max_param_diff(&seq, &mp);
        assert_eq!(d, 0.0, "P={p}: max param diff {d} (must be bitwise equal)");
    }
}

#[test]
fn mlp_explicit_lpp_matches_too() {
    let seq = fit(&mlp_cfg(Strategy::Sequential)).unwrap();
    // 6 nodes: input + 3 dense_relu + dense + loss; skew the split hard.
    let mp = fit(&mlp_cfg(Strategy::Model).partitions(3).lpp(vec![1, 1, 4])).unwrap();
    assert_eq!(max_param_diff(&seq, &mp), 0.0);
}

#[test]
fn resnet_model_parallel_matches_sequential_exactly() {
    // Conv + BN + skip connections crossing partitions.
    let seq = fit(&resnet_cfg(Strategy::Sequential)).unwrap();
    for p in [2, 4] {
        let mp = fit(&resnet_cfg(Strategy::Model).partitions(p)).unwrap();
        assert_eq!(
            loss_history(&seq),
            loss_history(&mp),
            "loss history diverged at P={p}"
        );
        assert_eq!(max_param_diff(&seq, &mp), 0.0, "P={p}");
    }
}

#[test]
fn microbatched_mp_matches_microbatched_seq() {
    // Pipelining (num_microbatches > 1) must not change the math either,
    // as long as sequential uses the same microbatching (BN sees the same
    // per-microbatch statistics).
    let seq = fit(&mlp_cfg(Strategy::Sequential).num_microbatches(3)).unwrap();
    let mp = fit(&mlp_cfg(Strategy::Model).partitions(3).num_microbatches(3)).unwrap();
    assert_eq!(max_param_diff(&seq, &mp), 0.0);
}

#[test]
fn one_f1b_matches_sequential_exactly() {
    // Under the 1F1B generator (P=1 degenerates to forward/backward
    // interleaved per microbatch, ascending), gradient accumulation order
    // is ascending-microbatch on every stage — so model-parallel 1F1B must
    // be bitwise equal to sequential execution under the same schedule.
    let seq = fit(
        &mlp_cfg(Strategy::Sequential)
            .num_microbatches(4)
            .schedule(ScheduleKind::OneF1B),
    )
    .unwrap();
    for p in [2, 3, 4] {
        let mp = fit(
            &mlp_cfg(Strategy::Model)
                .partitions(p)
                .num_microbatches(4)
                .schedule(ScheduleKind::OneF1B),
        )
        .unwrap();
        assert_eq!(
            loss_history(&seq),
            loss_history(&mp),
            "1F1B loss history diverged at P={p}"
        );
        let d = max_param_diff(&seq, &mp);
        assert_eq!(d, 0.0, "1F1B P={p}: max param diff {d} (must be bitwise equal)");
    }
}

#[test]
fn one_f1b_resnet_with_skips_matches_sequential_exactly() {
    // Conv + BN + skip connections crossing partitions, pipelined 1F1B.
    let seq = fit(
        &resnet_cfg(Strategy::Sequential)
            .num_microbatches(3)
            .schedule(ScheduleKind::OneF1B),
    )
    .unwrap();
    let mp = fit(
        &resnet_cfg(Strategy::Model)
            .partitions(4)
            .num_microbatches(3)
            .schedule(ScheduleKind::OneF1B),
    )
    .unwrap();
    assert_eq!(loss_history(&seq), loss_history(&mp));
    assert_eq!(max_param_diff(&seq, &mp), 0.0);
}

#[test]
fn schedules_agree_at_single_microbatch() {
    // With one microbatch there is nothing to reorder: GPipe and 1F1B
    // compile to the same compute sequence and must produce identical
    // weights.
    let a = fit(&mlp_cfg(Strategy::Model).partitions(3).schedule(ScheduleKind::GPipe)).unwrap();
    let b = fit(&mlp_cfg(Strategy::Model).partitions(3).schedule(ScheduleKind::OneF1B)).unwrap();
    assert_eq!(max_param_diff(&a, &b), 0.0);
}

#[test]
fn data_parallel_matches_bigbatch_sequential() {
    // DP with R replicas of microbatch m == sequential with microbatch R*m
    // (grad averaging == big-batch mean), up to float reassociation.
    // The MLP has no BN, so batch-size semantics are clean.
    let seq = fit(&TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .num_microbatches(2) // batch 8, as 2 microbatches of 4
        .steps(6)
        .lr(0.05)
        .seed(7))
    .unwrap();
    let dp = fit(&TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Data)
        .replicas(2)
        .microbatch(4)
        .num_microbatches(1) // batch 4 per replica, EBS 8
        .steps(6)
        .lr(0.05)
        .seed(7))
    .unwrap();
    let d = max_param_diff(&seq, &dp);
    assert!(d < 2e-5, "DP vs big-batch seq diff {d}");
}

#[test]
fn hybrid_matches_bigbatch_sequential() {
    let seq = fit(&TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .num_microbatches(2)
        .steps(5)
        .lr(0.05)
        .seed(3))
    .unwrap();
    let hy = fit(&TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Hybrid)
        .partitions(3)
        .replicas(2)
        .microbatch(4)
        .num_microbatches(1)
        .steps(5)
        .lr(0.05)
        .seed(3))
    .unwrap();
    let d = max_param_diff(&seq, &hy);
    assert!(d < 2e-5, "hybrid vs big-batch seq diff {d}");
}

#[test]
fn replicas_agree_after_training() {
    // Hybrid training must be deterministic end-to-end: same seed, same
    // topology -> bitwise identical weights.
    let a = fit(&mlp_cfg(Strategy::Hybrid).partitions(2).replicas(2)).unwrap();
    let b = fit(&mlp_cfg(Strategy::Hybrid).partitions(2).replicas(2)).unwrap();
    assert_eq!(max_param_diff(&a, &b), 0.0, "hybrid training not deterministic");
}

#[test]
fn losses_are_finite_and_improve_on_average() {
    let r = fit(&mlp_cfg(Strategy::Model).partitions(2).steps(30)).unwrap();
    assert!(r.history.iter().all(|m| m.loss.is_finite()));
    let first: f32 = r.history[..5].iter().map(|m| m.loss).sum::<f32>() / 5.0;
    let last: f32 = r.history[25..].iter().map(|m| m.loss).sum::<f32>() / 5.0;
    assert!(
        last < first,
        "loss should trend down: first5={first:.4} last5={last:.4}"
    );
}
