//! Property-based tests (seeded PRNG fuzzing — the offline build carries no
//! proptest crate, so the shrink-less equivalent is rolled by hand: many
//! random cases per property, each failure printing its seed).
//!
//! Properties:
//! 1. Random skip-topology graphs x random partitionings -> the message
//!    schedule completes under rendezvous semantics (no deadlock), and
//!    every cross edge appears exactly twice (fwd + bwd).
//! 2. Random LPP splits of a fixed MLP -> bitwise equivalence with the
//!    sequential run (the §6.1 guarantee, fuzzed).
//! 3. The auto load balancer never produces empty partitions and never
//!    exceeds 2x the ideal bottleneck on random graphs.
//! 4. hfmpi collectives agree with a scalar reference on random inputs.
//! 5. Random (graph, partitioning, m) x all four generators, compiled
//!    eager -> the program completes under BOTH buffered and rendezvous
//!    send semantics and every PostSend is completed by exactly one
//!    later WaitSend on the same rank.
//! 6. The same eager programs *replayed on the live rendezvous fabric*
//!    (one thread per rank, every comm op executed for real with payloads
//!    encoding their (class, edge, mb) identity) complete inside the
//!    watchdog with every received payload matching its channel — the
//!    abstract checker's verdict, validated against the real transport.

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::{zoo, ModelGraph};
use hyparflow::hfmpi::{tags, AllreduceAlgo, Transport, World};
use hyparflow::partition::{auto_lpp, MsgSchedule, Partitioning};
use hyparflow::rng::Rng;
use hyparflow::schedule::{Instr, Program, ScheduleKind, SendMode, SendSemantics};
use hyparflow::tensor::{Shape, Tensor};

/// Random conv/skip graph in the ResNet family: chains of conv-bn-relu with
/// random Add skip edges back to earlier same-shape nodes.
fn random_skip_graph(rng: &mut Rng) -> ModelGraph {
    let mut g = ModelGraph::new("fuzz", &[3, 8, 8]);
    let x = g.input();
    let mut cur = g.conv3x3(x, 4, 1);
    // Same-shape checkpoints eligible as skip sources.
    let mut checkpoints = vec![cur];
    let blocks = 2 + rng.below(6);
    for _ in 0..blocks {
        let c = g.conv3x3(cur, 4, 1);
        let b = g.batchnorm(c);
        let r = g.relu(b);
        cur = r;
        if rng.below(2) == 0 && !checkpoints.is_empty() {
            let src = checkpoints[rng.below(checkpoints.len())];
            cur = g.add(cur, src);
        }
        checkpoints.push(cur);
    }
    let p = g.gap(cur);
    let d = g.dense(p, 3);
    g.loss(d);
    g
}

/// Random LPP vector: contiguous, non-empty, sums to n.
fn random_lpp(rng: &mut Rng, n: usize, parts: usize) -> Vec<usize> {
    // parts-1 random cut points.
    let mut cuts: Vec<usize> = (0..parts - 1).map(|_| 1 + rng.below(n - 1)).collect();
    cuts.sort();
    cuts.dedup();
    while cuts.len() < parts - 1 {
        let c = 1 + rng.below(n - 1);
        if !cuts.contains(&c) {
            cuts.push(c);
            cuts.sort();
        }
    }
    let mut lpp = vec![];
    let mut prev = 0;
    for c in cuts {
        lpp.push(c - prev);
        prev = c;
    }
    lpp.push(n - prev);
    lpp
}

#[test]
fn prop_random_graphs_schedule_deadlock_free() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let g = random_skip_graph(&mut rng);
        g.validate().unwrap_or_else(|e| panic!("seed {seed}: invalid graph: {e}"));
        let n = g.num_nodes();
        let parts = 2 + rng.below(n.min(6) - 1);
        let lpp = random_lpp(&mut rng, n, parts);
        let pt = Partitioning::from_lpp(&g, &lpp)
            .unwrap_or_else(|e| panic!("seed {seed}: partition {lpp:?}: {e}"));
        let s = MsgSchedule::build(&pt);
        let steps = s
            .check_rendezvous()
            .unwrap_or_else(|stuck| panic!("seed {seed}: deadlock, stuck={stuck:?} lpp={lpp:?}"));
        assert_eq!(steps, pt.edges.len() * 2, "seed {seed}: edge coverage");
    }
}

#[test]
fn prop_gpipe_programs_rendezvous_safe_on_random_skip_topologies() {
    // The program-level generalization of the §6.3 claim: the multi-
    // microbatch GPipe instruction program (not just one microbatch's
    // message list) completes under rendezvous semantics on random skip
    // graphs and random contiguous partitionings, and covers every
    // (edge, microbatch) exactly twice (activation + error).
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed + 2000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let parts = 2 + rng.below(n.min(6) - 1);
        let lpp = random_lpp(&mut rng, n, parts);
        let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
        for m in [1usize, 2, 5] {
            let prog = Program::compile(&g, &pt, m, ScheduleKind::GPipe);
            let steps = prog.check(SendSemantics::Rendezvous).unwrap_or_else(|stuck| {
                panic!("seed {seed} m={m}: gpipe deadlock, stuck={stuck:?} lpp={lpp:?}")
            });
            assert_eq!(steps, pt.edges.len() * 2 * m, "seed {seed} m={m}: coverage");
        }
    }
}

#[test]
fn prop_one_f1b_programs_deadlock_free_on_random_skip_topologies() {
    // 1F1B inherently needs buffered sends (facing send pairs — see the
    // schedule module docs), which is what the hfmpi fabric provides; the
    // checker therefore runs in Buffered mode and proves every program is
    // executable: all receives are eventually satisfiable, full
    // (edge, microbatch) coverage, and the in-flight stash bound holds.
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed + 3000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let parts = 2 + rng.below(n.min(6) - 1);
        let lpp = random_lpp(&mut rng, n, parts);
        let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
        for m in [1usize, 3, 7] {
            let prog = Program::compile(&g, &pt, m, ScheduleKind::OneF1B);
            let steps = prog.check(SendSemantics::Buffered).unwrap_or_else(|stuck| {
                panic!("seed {seed} m={m}: 1f1b stuck={stuck:?} lpp={lpp:?}")
            });
            assert_eq!(steps, pt.edges.len() * 2 * m, "seed {seed} m={m}: coverage");
            for part in 0..parts {
                let bound = (parts - part).min(m);
                let peak = prog.peak_resident_microbatches(part);
                assert!(
                    peak <= bound,
                    "seed {seed} m={m} part {part}: resident {peak} > bound {bound}"
                );
            }
        }
    }
}

#[test]
fn prop_interleaved_and_zb_programs_conform_on_random_topologies() {
    // Random (graph, partitioning, m, v): the interleaved and zero-bubble
    // generators produce programs that complete under buffered sends (the
    // hfmpi fabric's semantics), cover every (cross-rank edge, microbatch)
    // exactly twice (activation + error), and pass the exactly-once /
    // consistent-tag send-recv pairing verifier.
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed + 7000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes(); // >= 11
        let ranks = 2 + rng.below(2); // 2..=3
        let v = 2 + rng.below(2); // 2..=3
        let lpp = random_lpp(&mut rng, n, ranks * v);
        let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
        let cross = pt
            .edges
            .iter()
            .filter(|e| e.src_part % ranks != e.dst_part % ranks)
            .count();
        for m in [1usize, 3, 7] {
            let prog = Program::compile(&g, &pt, m, ScheduleKind::Interleaved1F1B { v });
            let steps = prog.check(SendSemantics::Buffered).unwrap_or_else(|stuck| {
                panic!("seed {seed} R={ranks} v={v} m={m}: stuck={stuck:?} lpp={lpp:?}")
            });
            assert_eq!(steps, cross * 2 * m, "seed {seed} v={v} m={m}: coverage");
            prog.verify_message_pairing()
                .unwrap_or_else(|e| panic!("seed {seed} v={v} m={m}: pairing: {e}"));
        }
        let lpp = random_lpp(&mut rng, n, ranks);
        let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
        for m in [1usize, 3, 7] {
            let prog = Program::compile(&g, &pt, m, ScheduleKind::ZbH1);
            let steps = prog.check(SendSemantics::Buffered).unwrap_or_else(|stuck| {
                panic!("seed {seed} zb R={ranks} m={m}: stuck={stuck:?} lpp={lpp:?}")
            });
            assert_eq!(steps, pt.edges.len() * 2 * m, "seed {seed} zb m={m}: coverage");
            prog.verify_message_pairing()
                .unwrap_or_else(|e| panic!("seed {seed} zb m={m}: pairing: {e}"));
        }
    }
}

#[test]
fn prop_eager_programs_rendezvous_safe_on_random_topologies() {
    // Property 5: the eager (MPI_Isend-style) compile of *every* generator
    // is transport-agnostic on random skip graphs. Blocking 1F1B-family
    // programs need buffered sends (facing send pairs); rewriting their
    // sends into PostSend/WaitSend pairs must make the same instruction
    // order complete under rendezvous semantics too, with unchanged
    // (cross-rank edge, microbatch) coverage, and with every posted send
    // retired by exactly one later WaitSend on its own rank.
    for seed in 0..25u64 {
        let mut rng = Rng::new(seed + 11_000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(2); // 2..=3
        let v = 2 + rng.below(2); // 2..=3
        let kinds = [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B,
            ScheduleKind::Interleaved1F1B { v },
            ScheduleKind::ZbH1,
        ];
        for kind in kinds {
            let parts = if matches!(kind, ScheduleKind::Interleaved1F1B { .. }) {
                ranks * v
            } else {
                ranks
            };
            let lpp = random_lpp(&mut rng, n, parts);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            let cross = pt
                .edges
                .iter()
                .filter(|e| e.src_part % ranks != e.dst_part % ranks)
                .count();
            for m in [1usize, 3, 7] {
                let prog = Program::compile_with(&g, &pt, m, kind, SendMode::Eager);
                for sem in [SendSemantics::Buffered, SendSemantics::Rendezvous] {
                    let steps = prog.check(sem).unwrap_or_else(|stuck| {
                        panic!(
                            "seed {seed} {kind:?} m={m} {sem:?}: stuck={stuck:?} lpp={lpp:?}"
                        )
                    });
                    assert_eq!(steps, cross * 2 * m, "seed {seed} {kind:?} m={m}: coverage");
                }
                prog.verify_message_pairing()
                    .unwrap_or_else(|e| panic!("seed {seed} {kind:?} m={m}: pairing: {e}"));
                prog.verify_eager_pairing()
                    .unwrap_or_else(|e| panic!("seed {seed} {kind:?} m={m}: post/wait: {e}"));
            }
        }
    }
}

#[test]
fn prop_eager_programs_complete_on_live_rendezvous_fabric() {
    // Property 6: the abstract rendezvous verdict of property 5, validated
    // against the real transport. Each rank walks its compiled instruction
    // stream and executes the comm ops for real on a rendezvous world —
    // sends block until matched, waits park until the receive — so mere
    // completion inside the watchdog *is* the deadlock-freedom proof, and
    // payload checks pin channel identity (no cross-matched tags).
    let tag_of = |class: u64, edge: usize, mb: usize| {
        // Same (class, edge, mb) packing the CommEngine uses; the replayer
        // only needs it to be injective per channel.
        const MAX_MB: usize = 4096;
        class + (edge * MAX_MB + mb) as u64
    };
    let payload_of = |class: u64, edge: usize, mb: usize| {
        Tensor::new(
            Shape::new(&[3]),
            vec![class as f32, edge as f32, mb as f32],
        )
    };
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed + 17_000);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        let ranks = 2 + rng.below(2); // 2..=3
        let v = 2 + rng.below(2); // 2..=3
        let kinds = [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B,
            ScheduleKind::Interleaved1F1B { v },
            ScheduleKind::ZbH1,
        ];
        for kind in kinds {
            let parts = if matches!(kind, ScheduleKind::Interleaved1F1B { .. }) {
                ranks * v
            } else {
                ranks
            };
            let lpp = random_lpp(&mut rng, n, parts);
            let pt = Partitioning::from_lpp(&g, &lpp).unwrap();
            for m in [2usize, 5] {
                let prog = Program::compile_with(&g, &pt, m, kind, SendMode::Eager);
                World::run_with(
                    ranks,
                    Transport::Rendezvous,
                    Some(std::time::Duration::from_secs(20)),
                    |c| {
                        let r = c.rank();
                        let mut in_flight = std::collections::HashMap::new();
                        for i in prog.rank(r) {
                            match *i {
                                Instr::SendActivation { edge, peer, mb } => {
                                    c.send_owned(
                                        payload_of(tags::ACTIVATION, edge, mb),
                                        peer,
                                        tag_of(tags::ACTIVATION, edge, mb),
                                    );
                                }
                                Instr::SendError { edge, peer, mb } => {
                                    c.send_owned(
                                        payload_of(tags::ERROR, edge, mb),
                                        peer,
                                        tag_of(tags::ERROR, edge, mb),
                                    );
                                }
                                Instr::PostSendActivation { edge, peer, mb, handle } => {
                                    let req = c.isend_owned(
                                        payload_of(tags::ACTIVATION, edge, mb),
                                        peer,
                                        tag_of(tags::ACTIVATION, edge, mb),
                                    );
                                    in_flight.insert(handle, req);
                                }
                                Instr::PostSendError { edge, peer, mb, handle } => {
                                    let req = c.isend_owned(
                                        payload_of(tags::ERROR, edge, mb),
                                        peer,
                                        tag_of(tags::ERROR, edge, mb),
                                    );
                                    in_flight.insert(handle, req);
                                }
                                Instr::WaitSend { handle } => {
                                    let req = in_flight
                                        .remove(&handle)
                                        .unwrap_or_else(|| panic!("wait for unposted h{handle}"));
                                    c.wait(req);
                                }
                                Instr::RecvActivation { edge, peer, mb } => {
                                    let t = c.recv(peer, tag_of(tags::ACTIVATION, edge, mb));
                                    assert_eq!(
                                        t.data,
                                        payload_of(tags::ACTIVATION, edge, mb).data,
                                        "seed {seed} {kind:?} m={m} rank {r}: \
                                         activation payload e{edge} mb{mb}"
                                    );
                                }
                                Instr::RecvError { edge, peer, mb } => {
                                    let t = c.recv(peer, tag_of(tags::ERROR, edge, mb));
                                    assert_eq!(
                                        t.data,
                                        payload_of(tags::ERROR, edge, mb).data,
                                        "seed {seed} {kind:?} m={m} rank {r}: \
                                         error payload e{edge} mb{mb}"
                                    );
                                }
                                // Compute/stash/collective ops carry no p2p traffic.
                                _ => {}
                            }
                        }
                        assert!(
                            in_flight.is_empty(),
                            "seed {seed} {kind:?} m={m} rank {r}: {} unwaited posts",
                            in_flight.len()
                        );
                    },
                );
            }
        }
    }
}

#[test]
fn prop_one_f1b_random_lpp_training_equivalence() {
    // The numeric §6.1 guarantee under the 1F1B generator: any random
    // contiguous split, pipelined two-deep, trains bitwise-identically to
    // the sequential run under the same schedule.
    let seq = fit(
        &base_cfg(Strategy::Sequential)
            .num_microbatches(2)
            .schedule(ScheduleKind::OneF1B),
    )
    .unwrap();
    let g = zoo::mlp(8, &[8, 8, 8], 4);
    let n = g.num_nodes(); // 6
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed + 40);
        let parts = 2 + rng.below(3); // 2..4
        let lpp = random_lpp(&mut rng, n, parts);
        let mp = fit(
            &base_cfg(Strategy::Model)
                .partitions(parts)
                .lpp(lpp.clone())
                .num_microbatches(2)
                .schedule(ScheduleKind::OneF1B),
        )
        .unwrap();
        for ((ka, ta), (kb, tb)) in seq.params.iter().zip(mp.params.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(
                ta.max_abs_diff(tb),
                0.0,
                "seed {seed} lpp {lpp:?}: 1f1b params diverged"
            );
        }
    }
}

#[test]
fn prop_balancer_invariants_on_random_graphs() {
    for seed in 100..140u64 {
        let mut rng = Rng::new(seed);
        let g = random_skip_graph(&mut rng);
        let n = g.num_nodes();
        for parts in [2, 3, n.min(7)] {
            let lpp = auto_lpp(&g, parts).unwrap();
            assert_eq!(lpp.len(), parts, "seed {seed}");
            assert_eq!(lpp.iter().sum::<usize>(), n, "seed {seed}");
            assert!(lpp.iter().all(|&c| c > 0), "seed {seed}: {lpp:?}");
            let costs: Vec<f64> = {
                let mut acc = vec![];
                let mut i = 0;
                for &c in &lpp {
                    acc.push((i..i + c).map(|k| g.node_cost(k).flops.max(1.0)).sum());
                    i += c;
                }
                acc
            };
            let total: f64 = costs.iter().sum();
            let max = costs.iter().cloned().fold(0.0, f64::max);
            let maxnode = (0..n)
                .map(|k| g.node_cost(k).flops.max(1.0))
                .fold(0.0, f64::max);
            let ideal = (total / parts as f64).max(maxnode);
            assert!(
                max <= ideal * 2.0 + 1.0,
                "seed {seed} parts={parts}: bottleneck {max} vs ideal {ideal}"
            );
        }
    }
}

#[test]
fn prop_random_lpp_training_equivalence() {
    // Fuzz the *numeric* guarantee on the artifact-backed MLP: any random
    // contiguous split trains bitwise-identically to sequential.
    let seq = fit(&base_cfg(Strategy::Sequential)).unwrap();
    let g = zoo::mlp(8, &[8, 8, 8], 4);
    let n = g.num_nodes(); // 6
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed);
        let parts = 2 + rng.below(3); // 2..4
        let lpp = random_lpp(&mut rng, n, parts);
        let mp = fit(&base_cfg(Strategy::Model).partitions(parts).lpp(lpp.clone())).unwrap();
        for ((ka, ta), (kb, tb)) in seq.params.iter().zip(mp.params.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(
                ta.max_abs_diff(tb),
                0.0,
                "seed {seed} lpp {lpp:?}: params diverged"
            );
        }
    }
}

fn base_cfg(s: Strategy) -> TrainConfig {
    TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), s)
        .microbatch(4)
        .steps(3)
        .lr(0.05)
        .seed(21)
}

#[test]
fn prop_allreduce_matches_scalar_reference() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(7);
        let len = 1 + rng.below(300);
        let algo = match rng.below(3) {
            0 => AllreduceAlgo::Naive,
            1 => AllreduceAlgo::Ring,
            _ => AllreduceAlgo::RecursiveDoubling,
        };
        // Reference: sum of per-rank deterministic vectors.
        let make = |rank: usize| -> Vec<f32> {
            let mut r = Rng::new(seed * 1000 + rank as u64);
            (0..len).map(|_| r.uniform_in(-1.0, 1.0)).collect()
        };
        let mut want = vec![0.0f32; len];
        for rank in 0..n {
            for (w, v) in want.iter_mut().zip(make(rank)) {
                *w += v;
            }
        }
        let outs = World::run(n, |c| {
            let mut t = Tensor::new(Shape::new(&[len]), make(c.rank()));
            c.allreduce_sum_with(&mut t, algo).unwrap();
            t
        });
        for (rank, t) in outs.iter().enumerate() {
            for (i, (got, want)) in t.data.iter().zip(want.iter()).enumerate() {
                assert!(
                    (got - want).abs() < 1e-4,
                    "seed {seed} n={n} len={len} algo={algo:?} rank {rank} [{i}]: {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn prop_bcast_from_random_roots() {
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed + 500);
        let n = 2 + rng.below(7);
        let root = rng.below(n);
        let val = rng.uniform();
        World::run(n, move |c| {
            let mut t = if c.rank() == root {
                Tensor::full(&[5], val)
            } else {
                Tensor::zeros(&[5])
            };
            c.bcast(&mut t, root);
            assert_eq!(t.data, vec![val; 5], "seed {seed} n={n} root={root}");
        });
    }
}
