//! hftrace conformance: the runtime tracing subsystem against the same
//! bar the schedule IR holds itself to.
//!
//! (a) **Golden logical trace** — a 2-rank 1F1B MLP run records a
//!     deterministic per-rank event sequence (schedule-IR spans with
//!     their comm sub-spans and kernel spans), blessed under
//!     `rust/tests/golden/` via the same `HF_BLESS_GOLDEN` mechanism as
//!     the program-listing goldens. The listing embeds runtime artifact
//!     names and payload byte counts, so the file is *generated*: on a
//!     checkout without it the test writes it (and the in-process
//!     determinism assertion is what gives that blessing teeth).
//! (b) **Chrome export structure** — the merged multi-rank export of a
//!     real traced run passes the recursive-descent structural validator:
//!     parseable JSON, per-pid monotone timestamps, balanced B/E span
//!     stacks, every async send window opened exactly once and closed.
//! (c) **Observation only** — enabling tracing changes nothing: loss
//!     history and every parameter are bitwise identical to the
//!     untraced run.
//! (d) **Sim-vs-real cross-validation** — the pipeline-bubble fraction
//!     measured from a traced native run agrees with the calibrated
//!     simulator's prediction (the sim emits the same event schema, so
//!     both numbers come from `TraceReport::from_trace`) within
//!     `BUBBLE_TOLERANCE` for GPipe and 1F1B.
//!
//! Every test that calls `fit` serializes on `FIT_LOCK`: ranks are
//! threads in this process and the kernel pool size is global state.

use std::sync::{Mutex, MutexGuard};

use hyparflow::api::{fit, FitResult, Strategy, TrainConfig};
use hyparflow::graph::{zoo, ModelGraph};
use hyparflow::hfmpi::Transport;
use hyparflow::partition::Partitioning;
use hyparflow::schedule::{ScheduleKind, SendMode, SendSemantics};
use hyparflow::sim::{simulate_step_traced, Platform, SimConfig};
use hyparflow::trace::chrome::chrome_trace_json;
use hyparflow::trace::report::TraceReport;
use hyparflow::trace::validate::validate_chrome_trace;

/// `fit` spawns one thread per rank and sizes the global kernel pool, so
/// concurrent fits in one test binary would race each other's timing and
/// pool configuration. Timing-sensitive tests hold this for their whole
/// body; a poisoned lock (a prior test's panic) is still a valid lock.
static FIT_LOCK: Mutex<()> = Mutex::new(());

fn fit_lock() -> MutexGuard<'static, ()> {
    FIT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn loss_history(r: &FitResult) -> Vec<f32> {
    r.history.iter().map(|m| m.loss).collect()
}

fn max_param_diff(a: &FitResult, b: &FitResult) -> f32 {
    assert_eq!(a.params.len(), b.params.len(), "param sets differ");
    let mut worst = 0.0f32;
    for ((ka, ta), (kb, tb)) in a.params.iter().zip(b.params.iter()) {
        assert_eq!(ka, kb, "param key order mismatch");
        worst = worst.max(ta.max_abs_diff(tb));
    }
    worst
}

// ---------------------------------------------------------------------------
// (a) Golden logical trace
// ---------------------------------------------------------------------------

/// The golden scenario: the same MLP the program-listing goldens use,
/// model-parallel over 2 ranks under 1F1B with eager sends (pinned
/// explicitly — the CI conformance matrix flips `HF_EAGER_SENDS`, and the
/// logical sequence differs between transports by design). One step keeps
/// the listing reviewable; `native_threads(1)` keeps the kernel pool out
/// of the picture (the logical view is timestamp-free either way).
fn golden_cfg() -> TrainConfig {
    TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Model)
        .partitions(2)
        .schedule(ScheduleKind::OneF1B)
        .microbatch(4)
        .num_microbatches(4)
        .steps(1)
        .lr(0.05)
        .seed(21)
        .eager_sends(true)
        .trace(true)
        .native_threads(1)
}

fn logical(res: &FitResult) -> String {
    res.trace.as_ref().expect("trace(true) run must return a trace").logical_listing()
}

#[test]
fn golden_logical_trace_one_f1b_mlp() {
    let _guard = fit_lock();
    let listing = logical(&fit(&golden_cfg()).unwrap());
    // Determinism first: an identical run must record the identical
    // logical sequence (kinds, tags, payload bytes — no timestamps).
    let again = logical(&fit(&golden_cfg()).unwrap());
    assert_eq!(listing, again, "logical trace differs between identical runs");

    let path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/trace_one_f1b_mlp_2x4.txt");
    let got = format!(
        "hftrace logical listing: mlp(8, [8, 8, 8], 4), model-parallel P=2, one_f1b,\n\
         eager sends, microbatch=4, m=4, 1 step. Bless with\n\
         HF_BLESS_GOLDEN=1 cargo test --test trace_conformance\n{listing}"
    );
    if std::env::var("HF_BLESS_GOLDEN").is_ok() || !std::path::Path::new(path).exists() {
        std::fs::write(path, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(path).unwrap();
    assert_eq!(
        got, want,
        "logical trace diverged from {path}; if intended, bless with \
         HF_BLESS_GOLDEN=1 cargo test --test trace_conformance"
    );
}

// ---------------------------------------------------------------------------
// (b) Chrome export of a real run
// ---------------------------------------------------------------------------

#[test]
fn chrome_export_of_real_run_passes_structural_validation() {
    let _guard = fit_lock();
    // Two steps so the event stream crosses an OptStep boundary; eager
    // sends so the export carries async ("b"/"e") send windows. Kernel
    // threads follow HF_NATIVE_THREADS — the CI conformance matrix runs
    // this at 1 and 4 worker threads.
    let res = fit(
        &TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Model)
            .partitions(2)
            .schedule(ScheduleKind::OneF1B)
            .microbatch(4)
            .num_microbatches(4)
            .steps(2)
            .seed(3)
            .eager_sends(true)
            .trace(true),
    )
    .unwrap();
    let trace = res.trace.expect("traced run must return a trace");
    assert_eq!(trace.ranks.len(), 2);
    assert!(trace.num_events() > 0);

    let json = chrome_trace_json(&trace);
    let check = validate_chrome_trace(&json).expect("chrome export failed validation");
    assert_eq!(check.ranks, 2, "export must carry one pid per rank");
    assert!(check.spans > 0, "export has no complete B/E spans");
    // 1F1B over 2 ranks posts one activation and one error gradient per
    // microbatch per step across the stage boundary.
    assert!(check.windows >= 16, "expected >= 16 send windows, got {}", check.windows);

    // The traced run also aggregates: nonzero step time, nonzero compute,
    // and (eager sends) nonzero posted-send window time.
    let rep = TraceReport::from_trace(&trace);
    assert!(rep.step_secs > 0.0 && rep.compute_secs > 0.0);
    assert!(rep.window_secs > 0.0, "eager run recorded no send windows");
    assert!((0.0..=1.0).contains(&rep.bubble_frac), "bubble {}", rep.bubble_frac);
}

// ---------------------------------------------------------------------------
// (c) Tracing is observation-only
// ---------------------------------------------------------------------------

#[test]
fn tracing_is_observation_only() {
    let _guard = fit_lock();
    let cfg = || {
        TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Model)
            .partitions(2)
            .schedule(ScheduleKind::OneF1B)
            .microbatch(4)
            .num_microbatches(4)
            .steps(3)
            .lr(0.05)
            .seed(21)
    };
    let off = fit(&cfg().trace(false)).unwrap();
    let on = fit(&cfg().trace(true)).unwrap();
    assert!(off.trace.is_none(), "untraced run must not carry a trace");
    assert!(on.trace.is_some(), "traced run must carry a trace");
    assert_eq!(loss_history(&off), loss_history(&on), "tracing changed the loss history");
    assert_eq!(max_param_diff(&off, &on), 0.0, "tracing changed trained parameters");
}

// ---------------------------------------------------------------------------
// (d) Sim-vs-real cross-validation
// ---------------------------------------------------------------------------

/// Documented tolerance for |measured - simulated| pipeline-bubble
/// fraction. Deliberately coarse: the native run executes on a shared,
/// noisy host and the cost model is first-order (dispatch floor + a
/// saturating rate curve), so this cross-validates the *mechanism* —
/// fill/drain bubbles of the right magnitude — not microsecond accuracy.
/// For scale: P=2, m=8 gives a structural bubble of (P-1)/(m+P-1) ~ 0.11,
/// while a pipeline that accidentally serialized its stages would measure
/// ~0.5 and a broken trace ~1.0; both blow the tolerance.
const BUBBLE_TOLERANCE: f64 = 0.20;

/// Wide enough that per-kernel work dwarfs dispatch jitter on the
/// measured side: each dense microbatch kernel is ~2 MFLOP.
fn crossval_model() -> ModelGraph {
    zoo::mlp(256, &[256, 256, 256], 10)
}

/// The crossval fit configuration, parameterized by live-fabric
/// transport (the sim side mirrors it as [`SendSemantics`]).
fn crossval_cfg(kind: ScheduleKind, transport: Transport) -> TrainConfig {
    TrainConfig::new(crossval_model(), Strategy::Model)
        .partitions(2)
        .schedule(kind)
        .microbatch(16)
        .num_microbatches(8)
        .steps(4)
        .lr(0.01)
        .seed(7)
        .eager_sends(true)
        .trace(true)
        .native_threads(1)
        .transport(transport)
}

/// Min bubble fraction over the steady-state steps of a traced native
/// run (step 0 is warmup — cold caches, first-touch allocation; the min
/// is robust because transient stalls only ever inflate a step's bubble).
fn measured_bubble(kind: ScheduleKind, transport: Transport) -> f64 {
    let res = fit(&crossval_cfg(kind, transport)).unwrap();
    let trace = res.trace.expect("traced run must return a trace");
    let steps = trace.split_steps();
    assert_eq!(steps.len(), 4, "trace should split at every OptStep");
    steps[1..]
        .iter()
        .map(|s| TraceReport::from_trace(s).bubble_frac)
        .fold(f64::INFINITY, f64::min)
}

fn simulated_bubble(kind: ScheduleKind, sem: SendSemantics, calibration: &str) -> f64 {
    let g = crossval_model();
    // Same auto-partitioning `fit` resolves for Strategy::Model over 2
    // ranks (both schedules here are single-chunk).
    let pt = Partitioning::auto(&g, 2).unwrap();
    let mut cfg = SimConfig::new(Platform::skylake48(), 2, 1);
    cfg.ppn = Platform::skylake48().cores_per_node; // 1 core/rank = native_threads(1)
    cfg.microbatch = 16;
    cfg.num_microbatches = 8;
    cfg.schedule = kind;
    cfg.send_mode = SendMode::Eager;
    cfg.transport = sem;
    cfg.cost.apply_calibration(calibration).unwrap();
    let (_, trace) = simulate_step_traced(&g, &pt, &cfg);
    TraceReport::from_trace(&trace).bubble_frac
}

#[test]
fn measured_bubble_fraction_cross_validates_calibrated_simulator() {
    let _guard = fit_lock();
    // Calibrate the cost model on this host's kernels with the same
    // 1-worker pool the measured runs use. The third leg runs the live
    // rendezvous fabric against the sim's rendezvous semantics: waits now
    // measure real synchronization, and both sides must still agree.
    hyparflow::runtime::pool::set_num_threads(1);
    let cal = hyparflow::figures::measure_calibration().unwrap();
    for (kind, transport, sem) in [
        (ScheduleKind::GPipe, Transport::Buffered, SendSemantics::Buffered),
        (ScheduleKind::OneF1B, Transport::Buffered, SendSemantics::Buffered),
        (ScheduleKind::OneF1B, Transport::Rendezvous, SendSemantics::Rendezvous),
    ] {
        let sim = simulated_bubble(kind, sem, &cal);
        let real = measured_bubble(kind, transport);
        assert!(
            sim > 0.0 && sim < 1.0,
            "{} {}: sim bubble {sim:.3}",
            kind.label(),
            transport.label()
        );
        assert!(
            (real - sim).abs() <= BUBBLE_TOLERANCE,
            "{} {}: measured bubble {real:.3} vs simulated {sim:.3} disagree beyond {}",
            kind.label(),
            transport.label(),
            BUBBLE_TOLERANCE,
        );
    }
}

#[test]
fn traced_rendezvous_run_reports_real_overlap() {
    let _guard = fit_lock();
    // Under the rendezvous fabric an eager post's wait parks until the
    // matching receive, so the post→wait send windows cover real elapsed
    // time — and 1F1B computes while sends are in flight, so some of that
    // window time must overlap same-rank compute. (Under buffered both
    // numbers exist too, but windows there only measure enqueue latency;
    // rendezvous is where `overlap_secs` proves actual comm/compute
    // overlap on the live fabric.)
    hyparflow::runtime::pool::set_num_threads(1);
    let res = fit(&crossval_cfg(ScheduleKind::OneF1B, Transport::Rendezvous)).unwrap();
    let trace = res.trace.expect("traced run must return a trace");
    let rep = TraceReport::from_trace(&trace);
    assert!(rep.window_secs > 0.0, "rendezvous run recorded no send windows");
    assert!(
        rep.overlap_secs > 0.0,
        "rendezvous eager run shows no comm/compute overlap \
         (windows {:.6}s, overlap {:.6}s)",
        rep.window_secs,
        rep.overlap_secs
    );
    assert!(
        (0.0..=1.0).contains(&rep.overlap_frac),
        "overlap_frac {}",
        rep.overlap_frac
    );
}
