//! Scalar-vs-blocked kernel equivalence: the blocked, multi-threaded
//! kernels must be **bitwise identical** to the scalar references at every
//! thread count — this is the contract the sequential-vs-parallel training
//! equivalence tests stand on.
//!
//! Proptest-style: shapes are drawn from a seeded generator (deterministic
//! across runs, no external proptest crate — offline build), plus fixed
//! boundary shapes chosen to hit every tile/panel/block edge case and to
//! cross the kernels' serial-vs-parallel size thresholds.
//!
//! This lives in its own integration binary (own process) because the
//! sweeps drive the global thread-count knob, which in-process unit tests
//! must not touch concurrently.

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;
use hyparflow::rng::Rng;
use hyparflow::runtime::{kernels, pool};
use hyparflow::tensor::Tensor;

const THREAD_SWEEP: [usize; 3] = [1, 2, 4];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect()
}

#[test]
fn matmul_bitwise_random_shapes() {
    let mut rng = Rng::new(0xA11CE);
    // Fixed boundary shapes: exact tile/panel/k-block fits, one-off each
    // edge, and (64, 512, 64) crossing the parallel-matmul threshold.
    let mut shapes = vec![
        (1usize, 1usize, 1usize),
        (6, 256, 16),
        (7, 257, 17),
        (5, 255, 15),
        (12, 512, 32),
        (13, 300, 33),
        (64, 512, 64),
        (70, 300, 48),
    ];
    for _ in 0..24 {
        shapes.push((1 + rng.below(40), 1 + rng.below(320), 1 + rng.below(40)));
    }
    for (m, k, n) in shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let want = bits(&kernels::scalar::matmul(&a, &b, m, k, n));
        for t in THREAD_SWEEP {
            pool::set_num_threads(t);
            let got = bits(&kernels::matmul(&a, &b, m, k, n));
            assert_eq!(want, got, "matmul {m}x{k}x{n} at {t} threads");
        }
    }
    pool::set_num_threads(1);
}

#[test]
fn matmul_tn_bitwise_random_shapes() {
    let mut rng = Rng::new(0xB0B);
    let mut shapes = vec![
        (1usize, 1usize, 1usize),
        (256, 6, 16),
        (257, 7, 17),
        (300, 13, 33),
        (2048, 18, 32), // crosses the parallel threshold
    ];
    for _ in 0..16 {
        shapes.push((1 + rng.below(320), 1 + rng.below(40), 1 + rng.below(40)));
    }
    for (m, k, n) in shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, m * n);
        let want = bits(&kernels::scalar::matmul_tn(&a, &b, m, k, n));
        for t in THREAD_SWEEP {
            pool::set_num_threads(t);
            let got = bits(&kernels::matmul_tn(&a, &b, m, k, n));
            assert_eq!(want, got, "matmul_tn {m}x{k}x{n} at {t} threads");
        }
    }
    pool::set_num_threads(1);
}

#[test]
fn im2col_col2im_bitwise() {
    let mut rng = Rng::new(0xC01);
    // (n, c, h, w, kk, stride); the first crosses the element thresholds.
    for (n, c, h, w, kk, stride) in [
        (4usize, 8usize, 16usize, 16usize, 3usize, 1usize),
        (2, 3, 9, 7, 3, 2),
        (1, 5, 6, 6, 1, 1),
    ] {
        let x = Tensor::randn(&[n, c, h, w], 1.0, &mut rng);
        let (want_p, ho, wo) = kernels::scalar::im2col(&x, kk, stride);
        let f = c * kk * kk;
        let gp = randv(&mut rng, n * ho * wo * f);
        let want_g = kernels::scalar::col2im(&gp, n, c, h, w, kk, stride);
        for t in THREAD_SWEEP {
            pool::set_num_threads(t);
            let (got_p, gho, gwo) = kernels::im2col(&x, kk, stride);
            assert_eq!((ho, wo), (gho, gwo));
            assert_eq!(bits(&want_p), bits(&got_p), "im2col {n}x{c}x{h}x{w} k{kk}s{stride} at {t}T");
            let got_g = kernels::col2im(&gp, n, c, h, w, kk, stride);
            assert_eq!(
                bits(&want_g.data),
                bits(&got_g.data),
                "col2im {n}x{c}x{h}x{w} k{kk}s{stride} at {t}T"
            );
        }
    }
    pool::set_num_threads(1);
}

#[test]
fn conv_fwd_bwd_bitwise_random_shapes() {
    let mut rng = Rng::new(0xC02);
    // (n, c, kout, h, w, kk, stride); the first crosses the im2col/col2im
    // parallel thresholds.
    let mut cases = vec![
        (4usize, 8usize, 8usize, 16usize, 16usize, 3usize, 1usize),
        (2, 3, 4, 8, 8, 3, 2),
        (1, 4, 4, 7, 7, 1, 1),
        (2, 2, 6, 9, 5, 3, 1),
    ];
    for _ in 0..6 {
        cases.push((
            1 + rng.below(3),
            1 + rng.below(6),
            1 + rng.below(6),
            1 + rng.below(10),
            1 + rng.below(10),
            if rng.below(2) == 0 { 1 } else { 3 },
            1 + rng.below(2),
        ));
    }
    for (n, c, kout, h, w, kk, stride) in cases {
        let x = Tensor::randn(&[n, c, h, w], 1.0, &mut rng);
        let wt = Tensor::randn(&[kout, c, kk, kk], 0.5, &mut rng);
        let (ho, wo) = (h.div_ceil(stride), w.div_ceil(stride));
        let gy = Tensor::randn(&[n, kout, ho, wo], 1.0, &mut rng);
        let want_y = kernels::scalar::conv2d_fwd(&x, &wt, kk, stride);
        let (want_gx, want_gw) = kernels::scalar::conv2d_bwd(&x, &wt, &gy, kk, stride);
        for t in THREAD_SWEEP {
            pool::set_num_threads(t);
            let got_y = kernels::conv2d_fwd(&x, &wt, kk, stride);
            assert_eq!(
                bits(&want_y.data),
                bits(&got_y.data),
                "conv fwd n{n}c{c}k{kout} {h}x{w} k{kk}s{stride} at {t}T"
            );
            let (got_gx, got_gw) = kernels::conv2d_bwd(&x, &wt, &gy, kk, stride);
            assert_eq!(
                bits(&want_gx.data),
                bits(&got_gx.data),
                "conv bwd gx n{n}c{c}k{kout} {h}x{w} k{kk}s{stride} at {t}T"
            );
            assert_eq!(
                bits(&want_gw.data),
                bits(&got_gw.data),
                "conv bwd gw n{n}c{c}k{kout} {h}x{w} k{kk}s{stride} at {t}T"
            );
        }
    }
    pool::set_num_threads(1);
}

#[test]
fn dense_bitwise_random_shapes() {
    let mut rng = Rng::new(0xDE5E);
    for i in 0..10 {
        let (n, d, m) = (1 + rng.below(24), 1 + rng.below(200), 1 + rng.below(48));
        let relu = i % 2 == 0;
        let x = Tensor::randn(&[n, d], 1.0, &mut rng);
        let w = Tensor::randn(&[d, m], 0.5, &mut rng);
        let b = Tensor::randn(&[m], 0.1, &mut rng);
        let gy = Tensor::randn(&[n, m], 1.0, &mut rng);
        let want_y = kernels::scalar::dense_fwd(&x, &w, &b, relu);
        let (want_gx, want_gw, want_gb) = kernels::scalar::dense_bwd(&x, &w, &gy);
        for t in THREAD_SWEEP {
            pool::set_num_threads(t);
            let got_y = kernels::dense_fwd(&x, &w, &b, relu);
            assert_eq!(bits(&want_y.data), bits(&got_y.data), "dense fwd {n}x{d}x{m} at {t}T");
            let (got_gx, got_gw, got_gb) = kernels::dense_bwd(&x, &w, &gy);
            assert_eq!(bits(&want_gx.data), bits(&got_gx.data), "dense gx {n}x{d}x{m} at {t}T");
            assert_eq!(bits(&want_gw.data), bits(&got_gw.data), "dense gw {n}x{d}x{m} at {t}T");
            assert_eq!(bits(&want_gb.data), bits(&got_gb.data), "dense gb {n}x{d}x{m} at {t}T");
        }
    }
    pool::set_num_threads(1);
}

/// End-to-end acceptance: the same pipelined training run produces
/// bit-identical parameters and losses at 1, 2 and 4 kernel threads.
#[test]
fn training_bitwise_identical_across_thread_counts() {
    let run = |threads: usize| {
        let cfg = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Model)
            .partitions(2)
            .microbatch(4)
            .num_microbatches(2)
            .steps(3)
            .lr(0.05)
            .seed(13)
            .native_threads(threads);
        fit(&cfg).expect("fit")
    };
    let base = run(1);
    let base_params: Vec<Vec<u32>> = base.params.iter().map(|(_, t)| bits(&t.data)).collect();
    let base_loss: Vec<u32> = base.history.iter().map(|m| m.loss.to_bits()).collect();
    for t in [2usize, 4] {
        let r = run(t);
        let params: Vec<Vec<u32>> = r.params.iter().map(|(_, t)| bits(&t.data)).collect();
        let loss: Vec<u32> = r.history.iter().map(|m| m.loss.to_bits()).collect();
        assert_eq!(base_params, params, "params differ at {t} threads");
        assert_eq!(base_loss, loss, "loss history differs at {t} threads");
    }
    pool::set_num_threads(1);
}

/// Same acceptance on a real conv model (ResNet-20, one step).
#[test]
fn resnet_training_bitwise_identical_across_thread_counts() {
    let run = |threads: usize| {
        let cfg = TrainConfig::new(zoo::resnet20_v1(), Strategy::Sequential)
            .microbatch(4)
            .steps(1)
            .lr(0.01)
            .seed(5)
            .native_threads(threads);
        fit(&cfg).expect("fit")
    };
    let base = run(1);
    let base_params: Vec<Vec<u32>> = base.params.iter().map(|(_, t)| bits(&t.data)).collect();
    for t in [2usize, 4] {
        let r = run(t);
        let params: Vec<Vec<u32>> = r.params.iter().map(|(_, t)| bits(&t.data)).collect();
        assert_eq!(base_params, params, "resnet params differ at {t} threads");
    }
    pool::set_num_threads(1);
}
