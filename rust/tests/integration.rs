//! Integration tests across runtime + partitioner + engine + comm on the
//! real AOT artifacts (built by `make artifacts`).

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::comm::CommEngine;
use hyparflow::data::SyntheticDataset;
use hyparflow::engine::{EngineConfig, Trainer};
use hyparflow::graph::zoo;
use hyparflow::hfmpi::{AllreduceAlgo, World};
use hyparflow::partition::Partitioning;
use hyparflow::runtime::Runtime;

fn artifacts() -> std::path::PathBuf {
    hyparflow::api::default_artifacts_dir()
}

#[test]
fn training_reduces_loss_mlp() {
    let cfg = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .steps(40)
        .lr(0.1)
        .seed(1);
    let r = fit(&cfg).unwrap();
    let first = r.history[0].loss;
    let last = r.final_loss();
    assert!(last < first * 0.7, "loss {first:.3} -> {last:.3}");
}

#[test]
fn accuracy_recovers_from_glogits() {
    // Train long enough that accuracy beats chance (25% for 4 classes).
    let cfg = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .steps(80)
        .lr(0.1)
        .eval_batches(8)
        .seed(2);
    let r = fit(&cfg).unwrap();
    let eval = r.eval.unwrap();
    assert!(
        eval.accuracy > 0.3,
        "eval accuracy {} should beat 4-class chance",
        eval.accuracy
    );
}

#[test]
fn resnet20_two_partition_step_runs() {
    let cfg = TrainConfig::new(zoo::resnet20_v1(), Strategy::Model)
        .partitions(2)
        .microbatch(4)
        .steps(1)
        .seed(5);
    let r = fit(&cfg).unwrap();
    assert!(r.history[0].loss.is_finite());
    assert_eq!(r.params.len(), {
        let g = zoo::resnet20_v1();
        g.nodes.iter().map(|n| n.params.len()).sum::<usize>()
    });
}

#[test]
fn trainer_direct_api_single_rank() {
    // Drive the Trainer without `fit` to pin the per-step contract.
    let g = zoo::mlp(4, &[4], 3);
    let pt = Partitioning::auto(&g, 1).unwrap();
    World::run(1, |world| {
        let ce = CommEngine::new(world, 1, 0, 1, 0, usize::MAX, AllreduceAlgo::Auto);
        let rt = Runtime::open(artifacts()).unwrap();
        let data = SyntheticDataset::new(0, 3, &[4], 1.0);
        let cfg = EngineConfig { microbatch: 2, ..Default::default() };
        let mut tr = Trainer::new(&g, &pt, cfg, &ce, &rt, data).unwrap();
        let m = tr.train_step(0).unwrap();
        assert!(m.loss.is_finite());
        assert!(m.loss > 0.5 && m.loss < 5.0, "initial 3-class loss ~ln(3), got {}", m.loss);
        // Artifact warmup list covers everything the step executed.
        let names = tr.artifact_names();
        assert!(names.iter().any(|n| n.starts_with("denserelu")));
        assert!(names.iter().any(|n| n.starts_with("softmaxxent")));
    });
}

#[test]
fn eval_does_not_update_weights() {
    let g = zoo::mlp(4, &[4], 3);
    let pt = Partitioning::auto(&g, 1).unwrap();
    World::run(1, |world| {
        let ce = CommEngine::new(world, 1, 0, 1, 0, usize::MAX, AllreduceAlgo::Auto);
        let rt = Runtime::open(artifacts()).unwrap();
        let data = SyntheticDataset::new(0, 3, &[4], 1.0);
        let cfg = EngineConfig { microbatch: 2, ..Default::default() };
        let mut tr = Trainer::new(&g, &pt, cfg, &ce, &rt, data).unwrap();
        let before = tr.export_params();
        tr.evaluate(4).unwrap();
        let after = tr.export_params();
        for ((ka, ta), (kb, tb)) in before.iter().zip(after.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(ta.max_abs_diff(tb), 0.0, "evaluate mutated weights");
        }
    });
}

#[test]
fn vgg16_partitioned_forward_backward_runs() {
    // VGG-16 (maxpool + flatten + dense-relu path) across 3 partitions.
    let cfg = TrainConfig::new(zoo::vgg16(&[3, 32, 32], 10), Strategy::Model)
        .partitions(3)
        .microbatch(8)
        .steps(1)
        .lr(0.001)
        .seed(4);
    let r = fit(&cfg).unwrap();
    assert!(r.history[0].loss.is_finite());
    // No BN in VGG, so He-init logits have some spread; loss starts near
    // (but above) the ln(10) ~ 2.3 uniform level.
    assert!(
        r.history[0].loss > 1.5 && r.history[0].loss < 10.0,
        "loss {}",
        r.history[0].loss
    );
}

#[test]
fn resnet_v2_bottleneck_runs() {
    // v2 pre-activation blocks (bn->relu->conv chains + projections).
    let cfg = TrainConfig::new(zoo::resnet_v2(29, &[3, 32, 32], 10), Strategy::Model)
        .partitions(2)
        .microbatch(8)
        .steps(1)
        .lr(0.001)
        .seed(4);
    let r = fit(&cfg).unwrap();
    assert!(r.history[0].loss.is_finite());
}

#[test]
fn fused_conv_bn_relu_training_matches_unfused() {
    // The perf-pass graph rewrite must not change the math: train the
    // fused ResNet-20 and the plain one with identical hyperparameters
    // and compare loss histories (single fused XLA program vs three — same
    // ops, so only fusion-level reassociation noise is allowed).
    use hyparflow::graph::fuse::fuse_conv_bn_relu;
    let base = zoo::resnet20_v1();
    let (fused_graph, nfused) = fuse_conv_bn_relu(&base);
    assert!(nfused > 0);
    let mk = |g| {
        TrainConfig::new(g, Strategy::Sequential)
            .microbatch(4)
            .steps(2)
            .lr(0.01)
            .seed(11)
    };
    let plain = fit(&mk(base)).unwrap();
    let fused = fit(&mk(fused_graph)).unwrap();
    for (a, b) in plain.history.iter().zip(fused.history.iter()) {
        assert!(
            (a.loss - b.loss).abs() < 2e-3 * a.loss.abs().max(1.0),
            "fused diverged: {} vs {}",
            a.loss,
            b.loss
        );
    }
}

#[test]
fn lr_schedule_changes_trajectory() {
    use hyparflow::engine::LrSchedule;
    let base = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .steps(6)
        .lr(0.05)
        .seed(7);
    let constant = fit(&base.clone()).unwrap();
    let decayed = fit(&base.lr_schedule(LrSchedule::StepDecay {
        base: 0.05,
        boundaries: vec![2],
        factor: 0.1,
    }))
    .unwrap();
    // Identical until the boundary's effect lands (loss at step k reflects
    // updates through step k-1), then different.
    assert_eq!(constant.history[0].loss, decayed.history[0].loss);
    assert_eq!(constant.history[2].loss, decayed.history[2].loss);
    assert_ne!(constant.history[5].loss, decayed.history[5].loss);
}

#[test]
fn checkpoint_roundtrip_from_fit() {
    use hyparflow::engine::checkpoint;
    let cfg = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Model)
        .partitions(2)
        .microbatch(4)
        .steps(2)
        .seed(3);
    let r = fit(&cfg).unwrap();
    let path = std::env::temp_dir().join(format!("hf_integration_{}.ckpt", std::process::id()));
    checkpoint::save(&path, &r.params).unwrap();
    let back = checkpoint::load(&path).unwrap();
    assert_eq!(back.len(), r.params.len());
    for ((ka, ta), (kb, tb)) in r.params.iter().zip(back.iter()) {
        assert_eq!(ka, kb);
        assert_eq!(ta.max_abs_diff(tb), 0.0);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn cli_sim_calibration_round_trips_through_json() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_hyparflow");
    let out = std::env::temp_dir().join(format!("hf_calib_{}.json", std::process::id()));
    let sim_args = [
        "sim", "--model", "resnet20", "--partitions", "4", "--mb", "2", "--num-mb", "8",
        "--sched", "1f1b",
    ];
    let a = Command::new(bin)
        .args(sim_args)
        .args(["--calibrate", "--calib-out", out.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        a.status.success(),
        "calibrate run failed: {}",
        String::from_utf8_lossy(&a.stderr)
    );
    let json = std::fs::read_to_string(&out).unwrap();
    assert!(
        json.trim_start().starts_with('{'),
        "expected a JSON cost table in {}, got: {json}",
        out.display()
    );
    let b = Command::new(bin)
        .args(sim_args)
        .args(["--calib", out.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        b.status.success(),
        "calib-load run failed: {}",
        String::from_utf8_lossy(&b.stderr)
    );
    // The persisted table must reproduce the in-memory calibrated sim
    // exactly (the JSON round-trips every cost field bit-for-bit).
    let result_line = |o: &std::process::Output| {
        String::from_utf8_lossy(&o.stdout)
            .lines()
            .find(|l| l.contains("img/s"))
            .map(str::to_string)
            .unwrap_or_default()
    };
    let (ra, rb) = (result_line(&a), result_line(&b));
    assert!(!ra.is_empty(), "no sim result line in the calibrate run");
    assert_eq!(ra, rb, "sim with loaded calibration diverged from in-memory table");
    std::fs::remove_file(&out).ok();
}

#[test]
fn cli_rejects_bad_or_bare_sched_flag() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_hyparflow");
    // Unknown schedule: hard error listing the valid kinds (no silent
    // default).
    let out = Command::new(bin)
        .args(["sim", "--model", "resnet20", "--sched", "zigzag"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bad --sched value must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("gpipe|1f1b|interleaved_1f1b[:v=N]|zb_h1"),
        "stderr must list valid schedules: {err}"
    );
    // Bare --sched (the would-be value swallowed as the next flag) must
    // not silently fall back to the default schedule.
    let out = Command::new(bin)
        .args(["train", "--model", "mlp", "--sched"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "bare --sched must fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--sched requires a value"), "stderr: {err}");
}

#[test]
fn cli_rejects_malformed_env_flags() {
    // Strict env parsing: a typo'd HF_EAGER_SENDS / HF_TRACE value must
    // hard-error naming the variable, never silently pick a default
    // transport or tracing mode.
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_hyparflow");
    for var in ["HF_EAGER_SENDS", "HF_TRACE"] {
        let out = Command::new(bin)
            .args(["train", "--model", "mlp", "--steps", "1"])
            .env(var, "banana")
            .output()
            .unwrap();
        assert!(!out.status.success(), "{var}=banana must fail the run");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(var) && err.contains("banana"), "{var}: stderr: {err}");
        assert!(err.contains("1|true|on|0|false|off"), "{var}: stderr: {err}");
    }
}

#[test]
fn cli_train_trace_writes_valid_chrome_json() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_hyparflow");
    let path = std::env::temp_dir().join(format!("hf_trace_{}.json", std::process::id()));
    let out = Command::new(bin)
        .args(["train", "--model", "mlp", "--strategy", "model", "--partitions", "2"])
        .args(["--steps", "2", "--mb", "4", "--num-mb", "4", "--sched", "1f1b"])
        .args(["--trace", path.to_str().unwrap()])
        .env("HF_EAGER_SENDS", "1")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "traced train run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("bubble"), "report summary missing from stdout: {stdout}");
    let json = std::fs::read_to_string(&path).unwrap();
    let check = hyparflow::trace::validate::validate_chrome_trace(&json).unwrap();
    assert_eq!(check.ranks, 2, "expected one pid per rank");
    assert!(check.spans > 0, "no complete spans in the exported trace");
    assert!(check.windows > 0, "eager run exported no async send windows");
    std::fs::remove_file(&path).ok();
}

#[test]
fn throughput_metric_reported() {
    let cfg = TrainConfig::new(zoo::mlp(8, &[8, 8, 8], 4), Strategy::Sequential)
        .microbatch(4)
        .steps(3)
        .seed(1);
    let r = fit(&cfg).unwrap();
    assert!(r.img_per_sec > 0.0);
    assert!(r.wall_secs > 0.0);
    assert_eq!(r.history.len(), 3);
    assert_eq!(r.history[0].samples, 4);
}
