//! `cargo bench --bench fig13_hybrid_128nodes` — regenerates the paper's Fig 13.
//! Thin wrapper over `hyparflow::figures::fig13_hybrid_128nodes` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 13 — hybrid ResNet-1001 on up to 128 nodes ===");
    hyparflow::figures::fig13_hybrid_128nodes().print();
}
