//! `cargo bench --bench table3_resnet5k` — regenerates the paper's Table 3.
//! Thin wrapper over `hyparflow::figures::table3_resnet5k` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Table 3 — ResNet-5000 trainability at 331x331 ===");
    hyparflow::figures::table3_resnet5k().print();
}
