//! `cargo bench --bench fig11_vgg16_twonode` — regenerates the paper's Fig 11.
//! Thin wrapper over `hyparflow::figures::fig11_vgg16_twonode` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 11 — VGG-16 across two nodes, 8 partitions ===");
    hyparflow::figures::fig11_vgg16_twonode().print();
}
