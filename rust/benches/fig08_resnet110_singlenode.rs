//! `cargo bench --bench fig08_resnet110_singlenode` — regenerates the paper's Fig 8.
//! Thin wrapper over `hyparflow::figures::fig08_resnet110` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 8 — ResNet-110-v1, single Skylake node, up to 48 partitions ===");
    hyparflow::figures::fig08_resnet110().print();
}
