//! `cargo bench --bench fig10_resnet1001_singlenode` — regenerates the paper's Fig 10.
//! Thin wrapper over `hyparflow::figures::fig10_resnet1001` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 10 — ResNet-1001-v2, single Skylake node ===");
    hyparflow::figures::fig10_resnet1001().print();
}
