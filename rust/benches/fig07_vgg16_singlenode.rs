//! `cargo bench --bench fig07_vgg16_singlenode` — regenerates the paper's Fig 7.
//! Thin wrapper over `hyparflow::figures::fig07_vgg16` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 7 — VGG-16, single Skylake node, seq vs MP(8) vs DP ===");
    hyparflow::figures::fig07_vgg16().print();
}
