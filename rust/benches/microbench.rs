//! `cargo bench --bench microbench` — real (not simulated) measurements of
//! the hot-path components: runtime primitive dispatch, per-primitive
//! execution, hfmpi collectives (by algorithm and size), tensor fusion
//! on/off, and one real end-to-end training step per strategy.
//!
//! These are the numbers the §Perf pass in EXPERIMENTS.md tracks.

use hyparflow::api::{default_artifacts_dir, fit, Strategy, TrainConfig};
use hyparflow::graph::zoo;
use hyparflow::hfmpi::{AllreduceAlgo, FusionBuffer, World};
use hyparflow::runtime::Runtime;
use hyparflow::tensor::Tensor;
use hyparflow::util::{fmt_secs, Table};
use std::time::Instant;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    // Warmup once, then best-of-3 batches.
    f();
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..n {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / n as f64);
    }
    best
}

fn bench_runtime() {
    println!("--- primitive runtime (real measurements) ---");
    let rt = Runtime::open(default_artifacts_dir()).unwrap();
    let mut t = Table::new(&["artifact", "time/call", "GFLOP/s"]);

    let x = Tensor::zeros(&[2, 4]);
    let dt = time_n(200, || {
        rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap();
    });
    t.row(&["relu2 (dispatch floor)".into(), fmt_secs(dt), "-".into()]);

    // conv3x3 8x16x16 @32x32 stride 1: the ResNet-110 workhorse shape.
    let cx = Tensor::zeros(&[8, 16, 32, 32]);
    let cw = Tensor::zeros(&[16, 16, 3, 3]);
    let flops = 2.0 * 16.0 * 16.0 * 9.0 * 32.0 * 32.0 * 8.0;
    let dt = time_n(30, || {
        rt.exec("conv3x3_n8_c16_k16_h32_w32_s1.fwd", &[&cx, &cw]).unwrap();
    });
    t.row(&["conv3x3 16ch fwd (mb=8)".into(), fmt_secs(dt), format!("{:.1}", flops / dt / 1e9)]);

    let gy = Tensor::zeros(&[8, 16, 32, 32]);
    let dt = time_n(15, || {
        rt.exec("conv3x3_n8_c16_k16_h32_w32_s1.bwd", &[&cx, &cw, &gy]).unwrap();
    });
    t.row(&["conv3x3 16ch bwd (mb=8)".into(), fmt_secs(dt), format!("{:.1}", 2.0 * flops / dt / 1e9)]);

    // The e2e MLP's big matmul.
    let mx = Tensor::zeros(&[16, 4096]);
    let mw = Tensor::zeros(&[4096, 4096]);
    let mb = Tensor::zeros(&[4096]);
    let mflops = 2.0 * 16.0 * 4096.0 * 4096.0;
    let dt = time_n(20, || {
        rt.exec("denserelu_n16_d4096_m4096.fwd", &[&mx, &mw, &mb]).unwrap();
    });
    t.row(&["denserelu 4096x4096 fwd".into(), fmt_secs(dt), format!("{:.1}", mflops / dt / 1e9)]);

    // Blocked-vs-scalar flagship matmul (the BENCH_kernels.json headline;
    // full sweep: `cargo bench --bench kernel_bench`).
    use hyparflow::rng::Rng;
    use hyparflow::runtime::kernels;
    let mut rng = Rng::new(1);
    let ka: Vec<f32> = (0..256 * 2304).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let kb: Vec<f32> = (0..2304 * 256).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let kflops = 2.0 * 256.0 * 2304.0 * 256.0;
    let dt = time_n(2, || {
        let _ = kernels::scalar::matmul(&ka, &kb, 256, 2304, 256);
    });
    t.row(&["matmul 256x2304x256 scalar".into(), fmt_secs(dt), format!("{:.1}", kflops / dt / 1e9)]);
    let dt = time_n(8, || {
        let _ = kernels::matmul(&ka, &kb, 256, 2304, 256);
    });
    t.row(&["matmul 256x2304x256 blocked".into(), fmt_secs(dt), format!("{:.1}", kflops / dt / 1e9)]);
    t.print();
}

fn bench_collectives() {
    println!("--- hfmpi collectives (4 ranks, real threads) ---");
    let mut t = Table::new(&["op", "size", "time"]);
    for (len, label) in [(1usize << 10, "4 KiB"), (1 << 18, "1 MiB"), (1 << 22, "16 MiB")] {
        for algo in [AllreduceAlgo::Naive, AllreduceAlgo::Ring, AllreduceAlgo::RecursiveDoubling] {
            let secs = World::run(4, |c| {
                let mut x = Tensor::zeros(&[len]);
                c.barrier();
                let n = 10;
                let t0 = Instant::now();
                for _ in 0..n {
                    c.allreduce_sum_with(&mut x, algo).unwrap();
                }
                t0.elapsed().as_secs_f64() / n as f64
            })
            .into_iter()
            .fold(0.0f64, f64::max);
            t.row(&[format!("allreduce {algo:?}"), label.into(), fmt_secs(secs)]);
        }
    }
    t.print();
}

fn bench_fusion() {
    println!("--- tensor fusion (ResNet-110-shaped gradient set, 4 ranks) ---");
    // 220 small tensors like ResNet-110's per-layer grads.
    let mut t = Table::new(&["mode", "allreduce calls", "time/step"]);
    for (name, threshold) in
        [("unfused (1 per tensor)", 1usize), ("fused (64 MiB buckets)", 64 << 20)]
    {
        let (secs, calls) = World::run(4, |c| {
            let mut grads: Vec<Tensor> = (0..220)
                .map(|i| Tensor::zeros(&[if i % 2 == 0 { 2304 } else { 16 }]))
                .collect();
            let fb = FusionBuffer::new(threshold, AllreduceAlgo::Ring);
            c.barrier();
            let n = 5;
            let t0 = Instant::now();
            let mut calls = 0;
            for _ in 0..n {
                let mut refs: Vec<&mut Tensor> = grads.iter_mut().collect();
                calls = fb.allreduce_mean(c, &mut refs).unwrap();
            }
            (t0.elapsed().as_secs_f64() / n as f64, calls)
        })
        .into_iter()
        .fold((0.0f64, 0usize), |a, b| (a.0.max(b.0), a.1.max(b.1)));
        t.row(&[name.into(), calls.to_string(), fmt_secs(secs)]);
    }
    t.print();
}

fn bench_e2e_step() {
    println!("--- real end-to-end training steps (ResNet-20, synthetic CIFAR) ---");
    let mut t = Table::new(&["strategy", "ranks", "img/s", "step"]);
    let cases: Vec<(&str, Strategy, usize, usize)> = vec![
        ("sequential", Strategy::Sequential, 1, 1),
        ("model (P=2)", Strategy::Model, 2, 1),
        ("model (P=4)", Strategy::Model, 4, 1),
        ("data (R=2)", Strategy::Data, 1, 2),
        ("hybrid (2x2)", Strategy::Hybrid, 2, 2),
    ];
    for (name, s, p, r) in cases {
        let cfg = TrainConfig::new(zoo::resnet20_v1(), s)
            .partitions(p)
            .replicas(r)
            .microbatch(8)
            .steps(4)
            .seed(1);
        let res = fit(&cfg).unwrap();
        let secs: f64 =
            res.history.iter().skip(1).map(|m| m.step_secs).sum::<f64>() / 3.0;
        t.row(&[
            name.into(),
            (p * r).to_string(),
            format!("{:.1}", (8 * r) as f64 / secs),
            fmt_secs(secs),
        ]);
    }
    t.print();
}

fn main() {
    println!("=== microbench — real hot-path measurements ===");
    bench_runtime();
    bench_collectives();
    bench_fusion();
    bench_e2e_step();
}
