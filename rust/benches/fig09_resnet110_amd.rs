//! `cargo bench --bench fig09_resnet110_amd` — regenerates the paper's Fig 9.
//! Thin wrapper over `hyparflow::figures::fig09_resnet110_amd` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 9 — ResNet-110-v1 on AMD EPYC, up to 64 partitions ===");
    hyparflow::figures::fig09_resnet110_amd().print();
}
