//! `cargo bench --bench fig12_resnet1001_twonode` — regenerates the paper's Fig 12.
//! Thin wrapper over `hyparflow::figures::fig12_resnet1001_twonode` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 12 — ResNet-1001-v2 across two nodes, up to 96 partitions ===");
    hyparflow::figures::fig12_resnet1001_twonode().print();
}
