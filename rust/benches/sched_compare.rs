//! `cargo bench --bench sched_compare` — the four schedule generators
//! (gpipe, 1f1b, interleaved_1f1b:v=2, zb_h1) on the shared schedule IR:
//! step time, bubble fraction and peak memory for the default
//! ResNet-110 scenario (P=4, mb=4, 16 microbatches). Writes
//! `BENCH_sched.json` (override the path with `HF_BENCH_OUT`); the
//! narrative lives in EXPERIMENTS.md.

use hyparflow::figures;
use hyparflow::graph::zoo;
use hyparflow::sim::Platform;

fn main() {
    println!("=== sched_compare — gpipe/1f1b/interleaved/zb_h1 (simulated, shared IR) ===");
    let g = zoo::resnet110_v1();
    let (partitions, mb, num_mb) = (4usize, 4usize, 16usize);
    let pts = figures::sched_compare_data(&g, &Platform::skylake48(), partitions, mb, num_mb);
    figures::sched_table(&pts).print();
    let json = figures::sched_compare_json(&g.name, partitions, mb, num_mb, &pts);
    let out = std::env::var("HF_BENCH_OUT").unwrap_or_else(|_| "BENCH_sched.json".into());
    std::fs::write(&out, &json).expect("write bench json");
    println!("wrote {out}");
}
