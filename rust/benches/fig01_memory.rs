//! `cargo bench --bench fig01_memory` — regenerates the paper's Fig 1.
//! Thin wrapper over `hyparflow::figures::fig01_memory` (see that module for the
//! methodology and EXPERIMENTS.md for paper-vs-measured discussion).
fn main() {
    println!("=== Fig 1 — memory vs model/image size (trainability) ===");
    hyparflow::figures::fig01_memory().print();
}
