//! `cargo bench --bench kernel_bench` — scalar vs blocked matmul GFLOP/s
//! on the ResNet layer shapes behind the simulator's cost model, at 1/2/4
//! worker threads. Writes `BENCH_kernels.json` (override the path with
//! `HF_BENCH_OUT`) so the perf trajectory is tracked across PRs.
//!
//! Acceptance headline: >= 4x single-thread blocked-over-scalar speedup on
//! the 256x2304x256 flagship shape, near-linear scaling to 4 threads
//! (thread scaling is only visible when the machine has the cores — the
//! JSON records `threads_available` so a 1-core runner's flat curve is
//! interpretable).

use hyparflow::figures;

fn main() {
    println!("=== kernel_bench — scalar vs blocked native kernels ===");
    let cases = figures::kernel_bench(&[1, 2, 4]);
    figures::kernel_bench_table(&cases).print();
    if let Some(flag) = cases.iter().find(|c| c.shape.name.contains("flagship")) {
        println!(
            "flagship {}: scalar {:.1} GF/s, 1T speedup {:.2}x (target >= 4x)",
            c_name(flag),
            flag.scalar_gflops,
            flag.speedup_1t()
        );
    }
    let json = figures::kernel_bench_json(&cases);
    let out = std::env::var("HF_BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".into());
    std::fs::write(&out, &json).expect("write bench json");
    println!("wrote {out}");
}

fn c_name(c: &figures::KernelBenchCase) -> String {
    format!("{}x{}x{}", c.shape.m, c.shape.k, c.shape.n)
}
