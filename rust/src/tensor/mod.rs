//! Host tensor: a contiguous `f32` buffer plus a shape. This is the currency
//! of the coordinator — activations, partial errors, parameters and gradients
//! all travel as `Tensor`s between the PJRT runtime, the communication engine
//! and the optimizer.
//!
//! Layout is row-major (C order), matching both JAX defaults and the XLA
//! literal layout the runtime marshals to/from.

use crate::rng::Rng;
use std::fmt;

/// Shape = dimension list. Scalars are `[]`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn new(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Bytes when stored as f32.
    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    /// Parse "8,16,32,32" (empty string = scalar).
    pub fn parse(s: &str) -> anyhow::Result<Shape> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(Shape(vec![]));
        }
        let dims = s
            .split(',')
            .map(|d| d.trim().parse::<usize>())
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| anyhow::anyhow!("bad shape '{s}': {e}"))?;
        Ok(Shape(dims))
    }
}

impl fmt::Debug for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}]", self.0.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","))
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Shape,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Shape, data: Vec<f32>) -> Self {
        assert_eq!(shape.numel(), data.len(), "shape {shape} != data len {}", data.len());
        Tensor { shape, data }
    }

    pub fn zeros(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn ones(dims: &[usize]) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![1.0; n] }
    }

    pub fn full(dims: &[usize], v: f32) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: Shape(vec![]), data: vec![v] }
    }

    /// He-normal init (fan_in based), the standard conv/dense init used by the
    /// paper's Keras models.
    pub fn he_normal(dims: &[usize], fan_in: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / fan_in as f32).sqrt();
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape, data }
    }

    pub fn randn(dims: &[usize], std: f32, rng: &mut Rng) -> Self {
        let shape = Shape::new(dims);
        let n = shape.numel();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape, data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    /// First (batch) dimension; panics on scalars.
    pub fn batch(&self) -> usize {
        self.shape.0[0]
    }

    /// Split along dim 0 into `n` equal chunks. Panics if not divisible.
    pub fn split_batch(&self, n: usize) -> Vec<Tensor> {
        let b = self.batch();
        assert!(b % n == 0, "batch {b} not divisible into {n} chunks");
        let chunk_b = b / n;
        let stride: usize = self.shape.0[1..].iter().product();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut dims = self.shape.0.clone();
            dims[0] = chunk_b;
            let lo = i * chunk_b * stride;
            let hi = lo + chunk_b * stride;
            out.push(Tensor::new(Shape(dims), self.data[lo..hi].to_vec()));
        }
        out
    }

    /// Concatenate along dim 0. All inputs must agree on trailing dims.
    pub fn concat_batch(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let trailing = &parts[0].shape.0[1..];
        let mut total_b = 0;
        let mut data = Vec::with_capacity(parts.iter().map(|p| p.numel()).sum());
        for p in parts {
            assert_eq!(&p.shape.0[1..], trailing, "trailing dims mismatch in concat");
            total_b += p.shape.0[0];
            data.extend_from_slice(&p.data);
        }
        let mut dims = parts[0].shape.0.clone();
        dims[0] = total_b;
        Tensor::new(Shape(dims), data)
    }

    /// Elementwise in-place add (used for gradient accumulation across
    /// microbatches and for fan-in joins).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// L2 norm (used in tests and gradient diagnostics).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max absolute difference vs another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{} l2={:.4}", self.shape, self.l2())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_numel() {
        assert_eq!(Shape::new(&[2, 3, 4]).numel(), 24);
        assert_eq!(Shape::new(&[]).numel(), 1);
    }

    #[test]
    fn shape_parse_roundtrip() {
        let s = Shape::parse("8,16,32,32").unwrap();
        assert_eq!(s.dims(), &[8, 16, 32, 32]);
        assert_eq!(Shape::parse("").unwrap().rank(), 0);
        assert!(Shape::parse("2,x").is_err());
    }

    #[test]
    fn split_concat_roundtrip() {
        let t = Tensor::new(Shape::new(&[4, 3]), (0..12).map(|x| x as f32).collect());
        let parts = t.split_batch(2);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].shape.dims(), &[2, 3]);
        assert_eq!(parts[0].data, vec![0., 1., 2., 3., 4., 5.]);
        let back = Tensor::concat_batch(&parts);
        assert_eq!(back, t);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn split_not_divisible_panics() {
        Tensor::zeros(&[3, 2]).split_batch(2);
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::ones(&[2, 2]);
        let b = Tensor::full(&[2, 2], 2.0);
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5; 4]);
    }

    #[test]
    fn he_normal_std() {
        let mut rng = Rng::new(11);
        let t = Tensor::he_normal(&[64, 64, 3, 3], 9 * 64, &mut rng);
        let n = t.numel() as f32;
        let mean = t.data.iter().sum::<f32>() / n;
        let var = t.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
        let want = 2.0 / (9.0 * 64.0);
        assert!((var - want).abs() < want * 0.2, "var={var} want~{want}");
    }

    #[test]
    fn max_abs_diff_zero_on_clone() {
        let t = Tensor::randn(&[5, 5], 1.0, &mut Rng::new(0));
        assert_eq!(t.max_abs_diff(&t.clone()), 0.0);
    }
}
