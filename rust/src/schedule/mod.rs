//! The unified pipeline-schedule IR.
//!
//! One `(ModelGraph, Partitioning, num_microbatches)` triple compiles into
//! an explicit per-rank **instruction program** — compute ops
//! ([`Instr::FwdCompute`]/[`Instr::BwdCompute`]), message ops
//! (`Send`/`RecvActivation`, `Send`/`RecvError`), stash lifetime markers
//! ([`Instr::DropStash`]) and the step epilogue
//! ([`Instr::AllreduceGrads`], [`Instr::OptStep`]). Three consumers
//! interpret the *same* [`Program`] object:
//!
//! - the **Trainer** (`crate::engine`) executes it op by op against the
//!   runtime and the communication engine,
//! - the **simulator** (`crate::sim::pipeline`) replays it on the cost
//!   model, so simulated pipeline bubbles correspond to the instruction
//!   stream the engine actually runs,
//! - the **memory model** (`crate::mem`) derives peak activation residency
//!   from the program's stash live intervals
//!   ([`Program::peak_resident_microbatches`]) instead of assuming all
//!   microbatches stay resident.
//!
//! Two generators are provided:
//!
//! - [`ScheduleKind::GPipe`] — the paper's §5.3 fill/drain: all forwards
//!   (microbatch ascending), then all backwards (descending). Reproduces
//!   the original hand-rolled Trainer loop bitwise: same per-node compute
//!   order, same gradient-accumulation order, same message contents.
//! - [`ScheduleKind::OneF1B`] — PipeDream-style one-forward-one-backward
//!   with flush: stage `i` of `P` runs `min(P-1-i, m)` warmup forwards,
//!   then alternates forward/backward, then drains. At most `P - i`
//!   microbatch stashes are ever live on stage `i` (vs `m` under GPipe),
//!   which is what makes high `num_microbatches` affordable at fixed
//!   memory.
//!
//! **Message linearization.** Within one microbatch, message ops are
//! ordered by the same global key as `partition::MsgSchedule` (forward by
//! `(consumer node, producer node)`, backward by the mirrored reverse) —
//! the paper's §6.3 rank-sorted, deadlock-free order — with compute ops
//! interleaved at their dependency-minimal positions. GPipe programs are
//! therefore safe even under *rendezvous* (unbuffered synchronous) send
//! semantics, checked by [`Program::check`] and fuzzed in
//! `rust/tests/proptests.rs`.
//!
//! **1F1B requires buffered sends.** Under rendezvous semantics 1F1B can
//! deadlock even on a plain chain: stage `i` must get through its forward
//! send of microbatch `k+1` before posting the receive for stage `i+1`'s
//! error of microbatch `k`, while stage `i+1` symmetrically blocks on that
//! error send — two sends facing each other. Real pipelined systems
//! (PipeDream, Megatron) use asynchronous/buffered communication for
//! exactly this reason, and the hfmpi fabric buffers sends (MPI_Bsend
//! semantics), so the engine executes 1F1B safely. The checker models both:
//! [`SendSemantics::Rendezvous`] for the paper-faithful GPipe claim, and
//! [`SendSemantics::Buffered`] (sends complete immediately, receives wait
//! for a matching completed send) to validate that a program is executable
//! on the actual fabric. `one_f1b_needs_buffered_sends` in the tests below
//! pins the deadlock demonstration.

use crate::graph::{LayerKind, ModelGraph, NodeId};
use crate::partition::Partitioning;
use std::collections::HashMap;

/// Which pipeline schedule to compile.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScheduleKind {
    /// Fill/drain (paper §5.3): all forwards, then all backwards.
    #[default]
    GPipe,
    /// One-forward-one-backward with flush (PipeDream-style).
    OneF1B,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> anyhow::Result<ScheduleKind> {
        Ok(match s {
            "gpipe" => ScheduleKind::GPipe,
            "1f1b" | "one_f1b" | "onef1b" => ScheduleKind::OneF1B,
            _ => anyhow::bail!("unknown schedule '{s}' (gpipe|1f1b)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneF1B => "1f1b",
        }
    }
}

/// One instruction of a rank's program. `edge` indexes `Partitioning::edges`
/// (also the message-tag component); `peer` is the partner partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    /// Run the forward of `node` for microbatch `mb` (inputs are in the
    /// stash: local producers computed earlier, remote ones received).
    FwdCompute { node: NodeId, mb: usize },
    /// Run the backward of `node` for microbatch `mb` (output-gradient
    /// already accumulated from local consumers and received errors).
    BwdCompute { node: NodeId, mb: usize },
    /// Ship the producer's stashed activation along a cross edge.
    SendActivation { edge: usize, peer: usize, mb: usize },
    /// Receive a remote activation; stashed under the producer node id.
    RecvActivation { edge: usize, peer: usize, mb: usize },
    /// Ship the partial error (grad-layer payload, paper Eq. 6) back along
    /// a cross edge.
    SendError { edge: usize, peer: usize, mb: usize },
    /// Receive a partial error; accumulated into the producer's
    /// output-gradient.
    RecvError { edge: usize, peer: usize, mb: usize },
    /// Microbatch `mb`'s backward is complete on this rank: its activation
    /// stash and gradient accumulators are dead. The memory model reads
    /// stash lifetime from (first `FwdCompute`/`RecvActivation`, this).
    DropStash { mb: usize },
    /// Average accumulated gradients over microbatches and allreduce
    /// across replicas (one fused call per partition communicator).
    AllreduceGrads,
    /// Apply the optimizer update.
    OptStep,
}

impl Instr {
    /// Message identity for the deadlock checkers: (edge, mb, class) with
    /// class 0 = activation, 1 = error. `None` for non-message ops.
    fn msg_key(&self) -> Option<(usize, usize, u8, bool /*is_send*/, usize /*peer*/)> {
        match *self {
            Instr::SendActivation { edge, peer, mb } => Some((edge, mb, 0, true, peer)),
            Instr::RecvActivation { edge, peer, mb } => Some((edge, mb, 0, false, peer)),
            Instr::SendError { edge, peer, mb } => Some((edge, mb, 1, true, peer)),
            Instr::RecvError { edge, peer, mb } => Some((edge, mb, 1, false, peer)),
            _ => None,
        }
    }
}

/// Send-completion semantics for [`Program::check`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendSemantics {
    /// Synchronous (unbuffered) sends: a send completes only when the
    /// matching receive is at the head of the peer's program — the paper's
    /// §6.3 setting.
    Rendezvous,
    /// Buffered sends (MPI_Bsend — what the hfmpi fabric implements): a
    /// send completes immediately; a receive waits until the matching send
    /// has executed.
    Buffered,
}

/// A compiled per-rank instruction program for one training step.
#[derive(Clone, Debug)]
pub struct Program {
    pub kind: ScheduleKind,
    pub num_microbatches: usize,
    pub num_partitions: usize,
    ranks: Vec<Vec<Instr>>,
}

impl Program {
    /// Compile the schedule for `(g, pt, m)` under `kind`.
    pub fn compile(
        g: &ModelGraph,
        pt: &Partitioning,
        num_microbatches: usize,
        kind: ScheduleKind,
    ) -> Program {
        assert!(num_microbatches >= 1, "need at least one microbatch");
        let p = pt.num_partitions;
        let m = num_microbatches;
        let mut ranks = Vec::with_capacity(p);
        for part in 0..p {
            let mut prog = vec![];
            match kind {
                ScheduleKind::GPipe => {
                    for mb in 0..m {
                        fwd_phase(pt, part, mb, &mut prog);
                    }
                    for mb in (0..m).rev() {
                        bwd_phase(g, pt, part, mb, &mut prog);
                    }
                }
                ScheduleKind::OneF1B => {
                    // Warmup depth: how many forwards stage `part` runs
                    // before its first backward. Bounds in-flight stashes
                    // to w+1 <= P - part.
                    let w = (p - 1 - part).min(m);
                    for mb in 0..w {
                        fwd_phase(pt, part, mb, &mut prog);
                    }
                    for k in 0..m - w {
                        fwd_phase(pt, part, w + k, &mut prog);
                        bwd_phase(g, pt, part, k, &mut prog);
                    }
                    for k in m - w..m {
                        bwd_phase(g, pt, part, k, &mut prog);
                    }
                }
            }
            prog.push(Instr::AllreduceGrads);
            prog.push(Instr::OptStep);
            ranks.push(prog);
        }
        Program { kind, num_microbatches: m, num_partitions: p, ranks }
    }

    /// A forward-only single-microbatch program (evaluation path).
    pub fn forward_only(pt: &Partitioning) -> Program {
        let p = pt.num_partitions;
        let mut ranks = Vec::with_capacity(p);
        for part in 0..p {
            let mut prog = vec![];
            fwd_phase(pt, part, 0, &mut prog);
            ranks.push(prog);
        }
        Program {
            kind: ScheduleKind::GPipe,
            num_microbatches: 1,
            num_partitions: p,
            ranks,
        }
    }

    /// The instruction stream of one rank (== partition index).
    pub fn rank(&self, part: usize) -> &[Instr] {
        &self.ranks[part]
    }

    /// Peak number of microbatch stashes simultaneously live on `part`,
    /// from the program's own live intervals (first touch -> `DropStash`).
    /// GPipe yields `m`; 1F1B yields `min(P - part, m)`.
    pub fn peak_resident_microbatches(&self, part: usize) -> usize {
        let mut touched: Vec<bool> = vec![false; self.num_microbatches];
        let mut live = 0usize;
        let mut peak = 0usize;
        for instr in &self.ranks[part] {
            match *instr {
                Instr::FwdCompute { mb, .. } | Instr::RecvActivation { mb, .. } => {
                    if !touched[mb] {
                        touched[mb] = true;
                        live += 1;
                        peak = peak.max(live);
                    }
                }
                Instr::DropStash { mb } => {
                    if touched[mb] {
                        touched[mb] = false;
                        live -= 1;
                    }
                }
                _ => {}
            }
        }
        peak
    }

    /// Worst peak residency across all ranks.
    pub fn max_peak_resident_microbatches(&self) -> usize {
        (0..self.num_partitions)
            .map(|p| self.peak_resident_microbatches(p))
            .max()
            .unwrap_or(0)
    }

    /// Simulate the program's message ops under the given send semantics.
    /// Returns `Ok(matched message pairs)` if every rank completes, or
    /// `Err(stuck rank ids)` on deadlock. Compute/stash/epilogue ops never
    /// block and are skipped over.
    pub fn check(&self, sem: SendSemantics) -> Result<usize, Vec<usize>> {
        let p = self.ranks.len();
        let mut pc = vec![0usize; p];
        // Advance past non-message instructions.
        let skip = |rank: usize, pc: &mut [usize]| {
            while pc[rank] < self.ranks[rank].len()
                && self.ranks[rank][pc[rank]].msg_key().is_none()
            {
                pc[rank] += 1;
            }
        };
        for r in 0..p {
            skip(r, &mut pc);
        }
        let mut steps = 0usize;
        match sem {
            SendSemantics::Rendezvous => loop {
                let mut progressed = false;
                for a in 0..p {
                    if pc[a] >= self.ranks[a].len() {
                        continue;
                    }
                    let (edge, mb, class, is_send, peer) =
                        self.ranks[a][pc[a]].msg_key().unwrap();
                    if pc[peer] >= self.ranks[peer].len() {
                        continue;
                    }
                    let Some((e2, mb2, c2, send2, peer2)) =
                        self.ranks[peer][pc[peer]].msg_key()
                    else {
                        continue;
                    };
                    if peer2 == a && e2 == edge && mb2 == mb && c2 == class && send2 != is_send
                    {
                        pc[a] += 1;
                        pc[peer] += 1;
                        skip(a, &mut pc);
                        skip(peer, &mut pc);
                        steps += 1;
                        progressed = true;
                    }
                }
                if (0..p).all(|r| pc[r] >= self.ranks[r].len()) {
                    return Ok(steps);
                }
                if !progressed {
                    return Err((0..p).filter(|&r| pc[r] < self.ranks[r].len()).collect());
                }
            },
            SendSemantics::Buffered => {
                // sent[(edge, mb, class)] = completed sends not yet received.
                let mut sent: HashMap<(usize, usize, u8), usize> = HashMap::new();
                loop {
                    let mut progressed = false;
                    for a in 0..p {
                        loop {
                            skip(a, &mut pc);
                            if pc[a] >= self.ranks[a].len() {
                                break;
                            }
                            let (edge, mb, class, is_send, _peer) =
                                self.ranks[a][pc[a]].msg_key().unwrap();
                            if is_send {
                                *sent.entry((edge, mb, class)).or_insert(0) += 1;
                                pc[a] += 1;
                                progressed = true;
                            } else {
                                let slot = sent.entry((edge, mb, class)).or_insert(0);
                                if *slot > 0 {
                                    *slot -= 1;
                                    pc[a] += 1;
                                    steps += 1;
                                    progressed = true;
                                } else {
                                    break; // blocked on a send not yet issued
                                }
                            }
                        }
                    }
                    if (0..p).all(|r| pc[r] >= self.ranks[r].len()) {
                        return Ok(steps);
                    }
                    if !progressed {
                        return Err((0..p).filter(|&r| pc[r] < self.ranks[r].len()).collect());
                    }
                }
            }
        }
    }
}

/// Forward phase of one microbatch on one partition: message ops in the
/// §6.3 global order `(consumer node, producer node)` — the same
/// linearization `partition::MsgSchedule::build` produces — with
/// `FwdCompute` ops inserted at their dependency-minimal slots (a node's
/// compute goes after all messages keyed below it, so its receives precede
/// it and its sends follow it).
fn fwd_phase(pt: &Partitioning, part: usize, mb: usize, out: &mut Vec<Instr>) {
    let mut msgs: Vec<(usize, usize, Instr)> = vec![];
    for e in &pt.edges {
        if e.src_part == part {
            msgs.push((
                e.dst_node,
                e.src_node,
                Instr::SendActivation { edge: e.id, peer: e.dst_part, mb },
            ));
        }
        if e.dst_part == part {
            msgs.push((
                e.dst_node,
                e.src_node,
                Instr::RecvActivation { edge: e.id, peer: e.src_part, mb },
            ));
        }
    }
    msgs.sort_by_key(|&(d, s, _)| (d, s));
    let nodes = &pt.parts[part];
    let mut ni = 0usize;
    for (d, _s, m) in msgs {
        // Every local node strictly below the message key is computable
        // now; in particular a send's producer (s < d) and not yet the
        // receive's consumer (== d).
        while ni < nodes.len() && nodes[ni] < d {
            out.push(Instr::FwdCompute { node: nodes[ni], mb });
            ni += 1;
        }
        out.push(m);
    }
    while ni < nodes.len() {
        out.push(Instr::FwdCompute { node: nodes[ni], mb });
        ni += 1;
    }
}

/// Backward phase of one microbatch on one partition: the mirror
/// linearization, keyed `(Reverse(producer), Reverse(consumer))`, with
/// `BwdCompute` ops interleaved in reverse topological order and a final
/// `DropStash` marking the end of the microbatch's stash live interval.
fn bwd_phase(g: &ModelGraph, pt: &Partitioning, part: usize, mb: usize, out: &mut Vec<Instr>) {
    let mut msgs: Vec<(usize, usize, Instr)> = vec![];
    for e in &pt.edges {
        if e.dst_part == part {
            msgs.push((
                e.src_node,
                e.dst_node,
                Instr::SendError { edge: e.id, peer: e.src_part, mb },
            ));
        }
        if e.src_part == part {
            msgs.push((
                e.src_node,
                e.dst_node,
                Instr::RecvError { edge: e.id, peer: e.dst_part, mb },
            ));
        }
    }
    msgs.sort_by_key(|&(s, d, _)| (std::cmp::Reverse(s), std::cmp::Reverse(d)));
    let nodes = &pt.parts[part];
    let mut ni = 0usize; // index into nodes traversed in reverse
    let rev = |i: usize| nodes[nodes.len() - 1 - i];
    let mut emit = |node: NodeId, out: &mut Vec<Instr>| {
        if !matches!(g.nodes[node].kind, LayerKind::Input) {
            out.push(Instr::BwdCompute { node, mb });
        }
    };
    for (s, _d, m) in msgs {
        // Every local node strictly above the producer key runs its
        // backward now; in particular an error-send's consumer (d > s) and
        // not yet the error-receive's producer (== s).
        while ni < nodes.len() && rev(ni) > s {
            emit(rev(ni), out);
            ni += 1;
        }
        out.push(m);
    }
    while ni < nodes.len() {
        emit(rev(ni), out);
        ni += 1;
    }
    out.push(Instr::DropStash { mb });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn program(parts: usize, m: usize, kind: ScheduleKind) -> (Partitioning, Program) {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, parts).unwrap();
        let prog = Program::compile(&g, &pt, m, kind);
        (pt, prog)
    }

    #[test]
    fn gpipe_is_rendezvous_safe_and_covers_all_edges() {
        let (pt, prog) = program(4, 3, ScheduleKind::GPipe);
        let steps = prog.check(SendSemantics::Rendezvous).unwrap();
        assert_eq!(steps, pt.edges.len() * 2 * 3, "act+err per edge per mb");
        // Buffered semantics can only be more permissive.
        assert_eq!(prog.check(SendSemantics::Buffered).unwrap(), steps);
    }

    #[test]
    fn one_f1b_passes_buffered_check() {
        let (pt, prog) = program(4, 8, ScheduleKind::OneF1B);
        let steps = prog.check(SendSemantics::Buffered).unwrap();
        assert_eq!(steps, pt.edges.len() * 2 * 8);
    }

    #[test]
    fn one_f1b_needs_buffered_sends() {
        // The documented limitation: 1F1B over >1 stage deadlocks under
        // rendezvous semantics (facing sends), which is why pipelined
        // systems use buffered/asynchronous communication. If this ever
        // starts passing, the generator changed — revisit the module docs.
        let (_, prog) = program(3, 6, ScheduleKind::OneF1B);
        assert!(prog.check(SendSemantics::Rendezvous).is_err());
    }

    #[test]
    fn gpipe_residency_is_m() {
        let (_, prog) = program(4, 6, ScheduleKind::GPipe);
        for part in 0..4 {
            assert_eq!(prog.peak_resident_microbatches(part), 6);
        }
    }

    #[test]
    fn one_f1b_residency_bounded_by_depth() {
        let (_, prog) = program(4, 16, ScheduleKind::OneF1B);
        for part in 0..4 {
            assert_eq!(prog.peak_resident_microbatches(part), 4 - part);
        }
        // And never exceeds m when the pipeline is shallow vs m.
        let (_, small) = program(4, 2, ScheduleKind::OneF1B);
        assert!(small.max_peak_resident_microbatches() <= 2);
    }

    #[test]
    fn single_partition_one_f1b_interleaves() {
        // P=1 degenerates to fwd/bwd per microbatch, ascending.
        let g = zoo::mlp(8, &[8, 8], 4);
        let pt = Partitioning::auto(&g, 1).unwrap();
        let prog = Program::compile(&g, &pt, 3, ScheduleKind::OneF1B);
        let mut seen = vec![];
        for i in prog.rank(0) {
            match *i {
                Instr::FwdCompute { mb, node } if node == 0 => seen.push(('f', mb)),
                Instr::DropStash { mb } => seen.push(('d', mb)),
                _ => {}
            }
        }
        assert_eq!(seen, vec![('f', 0), ('d', 0), ('f', 1), ('d', 1), ('f', 2), ('d', 2)]);
        assert_eq!(prog.peak_resident_microbatches(0), 1);
    }

    #[test]
    fn compute_ops_respect_dependencies() {
        // In every rank's stream: a node's FwdCompute comes after the
        // RecvActivation of each of its remote inputs and before the
        // SendActivation of each of its out-edges (same microbatch).
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let prog = Program::compile(&g, &pt, 2, ScheduleKind::OneF1B);
        for part in 0..4 {
            let stream = prog.rank(part);
            let pos = |pred: &dyn Fn(&Instr) -> bool| -> usize {
                stream.iter().position(|i| pred(i)).unwrap()
            };
            for e in &pt.edges {
                for mb in 0..2 {
                    if e.dst_part == part {
                        let recv = pos(&|i: &Instr| {
                            matches!(i, Instr::RecvActivation { edge, mb: m, .. }
                                     if *edge == e.id && *m == mb)
                        });
                        let consume = pos(&|i: &Instr| {
                            matches!(i, Instr::FwdCompute { node, mb: m }
                                     if *node == e.dst_node && *m == mb)
                        });
                        assert!(recv < consume, "part {part} edge {} mb {mb}", e.id);
                    }
                    if e.src_part == part {
                        let produce = pos(&|i: &Instr| {
                            matches!(i, Instr::FwdCompute { node, mb: m }
                                     if *node == e.src_node && *m == mb)
                        });
                        let send = pos(&|i: &Instr| {
                            matches!(i, Instr::SendActivation { edge, mb: m, .. }
                                     if *edge == e.id && *m == mb)
                        });
                        assert!(produce < send, "part {part} edge {} mb {mb}", e.id);
                    }
                }
            }
        }
    }

    #[test]
    fn epilogue_present_once_per_rank() {
        let (_, prog) = program(3, 4, ScheduleKind::OneF1B);
        for part in 0..3 {
            let n_ar = prog
                .rank(part)
                .iter()
                .filter(|i| matches!(i, Instr::AllreduceGrads))
                .count();
            let n_opt = prog
                .rank(part)
                .iter()
                .filter(|i| matches!(i, Instr::OptStep))
                .count();
            assert_eq!((n_ar, n_opt), (1, 1));
        }
    }

    #[test]
    fn ir_message_order_matches_msg_schedule() {
        // The IR's per-microbatch message linearization and
        // `partition::MsgSchedule::build` implement the same §6.3 rule.
        // Pin them against divergence: the message ops of a one-microbatch
        // GPipe program must equal MsgSchedule's program op-for-op.
        use crate::partition::{MsgDir, MsgSchedule};
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let prog = Program::compile(&g, &pt, 1, ScheduleKind::GPipe);
        let ms = MsgSchedule::build(&pt);
        for part in 0..4 {
            let got: Vec<(MsgDir, usize, usize)> = prog
                .rank(part)
                .iter()
                .filter_map(|i| match *i {
                    Instr::SendActivation { edge, peer, .. } => {
                        Some((MsgDir::SendActivation, peer, edge))
                    }
                    Instr::RecvActivation { edge, peer, .. } => {
                        Some((MsgDir::RecvActivation, peer, edge))
                    }
                    Instr::SendError { edge, peer, .. } => {
                        Some((MsgDir::SendError, peer, edge))
                    }
                    Instr::RecvError { edge, peer, .. } => {
                        Some((MsgDir::RecvError, peer, edge))
                    }
                    _ => None,
                })
                .collect();
            let want: Vec<(MsgDir, usize, usize)> = ms.programs[part]
                .iter()
                .map(|m| (m.dir, m.peer, m.edge))
                .collect();
            assert_eq!(got, want, "partition {part} diverged from MsgSchedule");
        }
    }

    #[test]
    fn schedule_kind_parses() {
        assert_eq!(ScheduleKind::parse("gpipe").unwrap(), ScheduleKind::GPipe);
        assert_eq!(ScheduleKind::parse("1f1b").unwrap(), ScheduleKind::OneF1B);
        assert!(ScheduleKind::parse("zigzag").is_err());
    }
}
