//! The unified pipeline-schedule IR.
//!
//! One `(ModelGraph, Partitioning, num_microbatches)` triple compiles into
//! an explicit per-rank **instruction program** — compute ops
//! ([`Instr::FwdCompute`]/[`Instr::BwdCompute`], plus the zero-bubble split
//! pair [`Instr::BwdInput`]/[`Instr::BwdWeight`]), message ops
//! (`Send`/`RecvActivation`, `Send`/`RecvError`), stash lifetime markers
//! ([`Instr::DropStash`]) and the step epilogue
//! ([`Instr::AllreduceGrads`], [`Instr::OptStep`]). Three consumers
//! interpret the *same* [`Program`] object:
//!
//! - the **Trainer** (`crate::engine`) executes it op by op against the
//!   runtime and the communication engine,
//! - the **simulator** (`crate::sim::pipeline`) replays it on the cost
//!   model, so simulated pipeline bubbles correspond to the instruction
//!   stream the engine actually runs,
//! - the **memory model** (`crate::mem`) derives peak activation residency
//!   from the program's stash live intervals
//!   ([`Program::peak_resident_microbatches`],
//!   [`Program::peak_activation_bytes`]) instead of assuming all
//!   microbatches stay resident.
//!
//! Four generators are provided:
//!
//! - [`ScheduleKind::GPipe`] — the paper's §5.3 fill/drain: all forwards
//!   (microbatch ascending), then all backwards (descending). Reproduces
//!   the original hand-rolled Trainer loop bitwise: same per-node compute
//!   order, same gradient-accumulation order, same message contents.
//! - [`ScheduleKind::OneF1B`] — PipeDream-style one-forward-one-backward
//!   with flush: stage `i` of `P` runs `min(P-1-i, m)` warmup forwards,
//!   then alternates forward/backward, then drains. At most `P - i`
//!   microbatch stashes are ever live on stage `i` (vs `m` under GPipe),
//!   which is what makes high `num_microbatches` affordable at fixed
//!   memory.
//! - [`ScheduleKind::Interleaved1F1B`] — Megatron-style virtual stages:
//!   the partitioner cuts the model into `P * v` contiguous chunks and
//!   assigns stage `s` to rank `s % P` (round-robin), so each rank owns
//!   `v` chunks and the fill/drain bubble shrinks by ~1/v. Compute ops
//!   carry their stage index; messages between two stages of the *same*
//!   rank are elided (the producer's activation is already in the rank's
//!   stash — chunk order guarantees it precedes the consumer).
//! - [`ScheduleKind::ZbH1`] — zero-bubble ZB-H1 (Qi et al., PAPERS.md):
//!   backward splits into `BwdInput` (input gradient — the only part
//!   downstream stages wait on) and `BwdWeight` (parameter gradient —
//!   freely schedulable). Each rank defers its weight-grad passes by its
//!   warmup depth, so that work lands in what 1F1B leaves as drain
//!   bubble, and `AllreduceGrads` runs only after the last `BwdWeight`.
//!
//! **Message linearization.** Within one microbatch, message ops are
//! ordered by the same global key as `partition::MsgSchedule` (forward by
//! `(consumer node, producer node)`, backward by the mirrored reverse) —
//! the paper's §6.3 rank-sorted, deadlock-free order — with compute ops
//! interleaved at their dependency-minimal positions. GPipe programs are
//! therefore safe even under *rendezvous* (unbuffered synchronous) send
//! semantics, checked by [`Program::check`] and fuzzed in
//! `rust/tests/proptests.rs`; all schedules are checked for exactly-once,
//! peer- and order-consistent pairing by
//! [`Program::verify_message_pairing`] and conformance-tested end to end
//! in `rust/tests/schedule_conformance.rs`.
//!
//! **Blocking 1F1B-family schedules require buffered sends.** Under
//! rendezvous semantics blocking 1F1B can deadlock even on a plain chain:
//! stage `i` must get through its forward send of microbatch `k+1` before
//! posting the receive for stage `i+1`'s error of microbatch `k`, while
//! stage `i+1` symmetrically blocks on that error send — two sends facing
//! each other. Real pipelined systems (PipeDream, Megatron) use
//! asynchronous communication for exactly this reason. The checker models
//! both transports: [`SendSemantics::Rendezvous`] (a send completes only
//! against a posted receive — the paper-faithful §6.3 setting) and
//! [`SendSemantics::Buffered`] (MPI_Bsend — what the hfmpi fabric
//! implements: sends complete immediately, receives wait for a matching
//! completed send). `one_f1b_needs_buffered_sends` in the tests below pins
//! the deadlock demonstration as a regression canary.
//!
//! **Eager sends make every generator rendezvous-safe.** Compiling with
//! [`SendMode::Eager`] splits each blocking send into an MPI_Isend-style
//! pair: [`Instr::PostSendActivation`]/[`Instr::PostSendError`] initiate
//! the transfer and never block, and the matching [`Instr::WaitSend`]
//! (placed at the end of the microbatch's live interval, just before its
//! `DropStash`, or flushed before `AllreduceGrads`) completes it. Because
//! a posted send cannot face another send, the facing-send deadlock
//! disappears and all four generators' eager programs complete under
//! *rendezvous* semantics — machine-checked per kind x random topology x
//! m in `rust/tests/schedule_conformance.rs`. The send buffer stays live
//! from post to wait (the MPI_Isend contract): activation payloads alias
//! the stash (already live until `DropStash`), error payloads are pinned
//! in the engine's in-flight table and counted by
//! [`Program::peak_activation_bytes`]; the concurrency itself is bounded
//! by [`Program::peak_in_flight_sends`] and budget-checked against the
//! message-tag space at `CommEngine` construction.

mod interleaved;

use crate::graph::{LayerKind, ModelGraph, NodeId};
use crate::partition::Partitioning;
use std::collections::HashMap;

/// The `--sched` values [`ScheduleKind::parse`] accepts.
pub const VALID_SCHEDULES: &str = "gpipe|1f1b|interleaved_1f1b[:v=N]|zb_h1";

/// Which pipeline schedule to compile.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScheduleKind {
    /// Fill/drain (paper §5.3): all forwards, then all backwards.
    #[default]
    GPipe,
    /// One-forward-one-backward with flush (PipeDream-style).
    OneF1B,
    /// Interleaved 1F1B with `v` virtual stages per rank (Megatron-style).
    Interleaved1F1B { v: usize },
    /// Zero-bubble ZB-H1: backward split into input-grad and weight-grad
    /// ops, weight-grad work deferred into the drain bubble.
    ZbH1,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> anyhow::Result<ScheduleKind> {
        if let Some(rest) = s.strip_prefix("interleaved_1f1b") {
            let v = match rest {
                "" => 2,
                _ => rest
                    .strip_prefix(":v=")
                    .and_then(|n| n.parse::<usize>().ok())
                    .filter(|&v| v >= 1)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "bad schedule '{s}': expected interleaved_1f1b[:v=N] with \
                             N >= 1 (valid schedules: {VALID_SCHEDULES})"
                        )
                    })?,
            };
            // v=1 is plain 1F1B; normalize so downstream matches stay simple.
            return Ok(if v == 1 {
                ScheduleKind::OneF1B
            } else {
                ScheduleKind::Interleaved1F1B { v }
            });
        }
        Ok(match s {
            "gpipe" => ScheduleKind::GPipe,
            "1f1b" | "one_f1b" | "onef1b" => ScheduleKind::OneF1B,
            "zb_h1" | "zbh1" => ScheduleKind::ZbH1,
            _ => anyhow::bail!("unknown schedule '{s}' (valid schedules: {VALID_SCHEDULES})"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneF1B => "1f1b",
            ScheduleKind::Interleaved1F1B { .. } => "interleaved_1f1b",
            ScheduleKind::ZbH1 => "zb_h1",
        }
    }

    /// Display label including parameters (`interleaved_1f1b:v=2`).
    pub fn label(&self) -> String {
        match self {
            ScheduleKind::Interleaved1F1B { v } => format!("interleaved_1f1b:v={v}"),
            k => k.name().to_string(),
        }
    }

    /// Virtual stages (model chunks) each rank owns under this schedule.
    pub fn virtual_stages(&self) -> usize {
        match self {
            ScheduleKind::Interleaved1F1B { v } => *v,
            _ => 1,
        }
    }

    /// The stage-level partitioning for `ranks` pipeline ranks: flat
    /// schedules get one stage per rank; interleaved gets `ranks * v`
    /// contiguous chunks (stage `s` runs on rank `s % ranks`).
    pub fn partitioning(&self, g: &ModelGraph, ranks: usize) -> anyhow::Result<Partitioning> {
        Partitioning::auto(g, ranks * self.virtual_stages())
    }
}

/// One instruction of a rank's program. `edge` indexes `Partitioning::edges`
/// (also the message-tag component); `peer` is the partner *rank*; `stage`
/// is the stage-level partition a compute op belongs to (equal to the rank
/// for flat schedules, `chunk * ranks + rank` under interleaved).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    /// Run the forward of `node` for microbatch `mb` (inputs are in the
    /// stash: local producers computed earlier, remote ones received).
    FwdCompute { node: NodeId, stage: usize, mb: usize },
    /// Run the backward of `node` for microbatch `mb` (output-gradient
    /// already accumulated from local consumers and received errors).
    BwdCompute { node: NodeId, stage: usize, mb: usize },
    /// ZB-H1 split backward, part 1: the input gradient — the only piece
    /// downstream stages wait on. Parameter gradients computed alongside
    /// are parked until the matching `BwdWeight` retires them.
    BwdInput { node: NodeId, stage: usize, mb: usize },
    /// ZB-H1 split backward, part 2: accumulate the parked parameter
    /// gradients of `(node, mb)` — freely schedulable into drain bubbles.
    BwdWeight { node: NodeId, stage: usize, mb: usize },
    /// Ship the producer's stashed activation along a cross edge.
    SendActivation { edge: usize, peer: usize, mb: usize },
    /// Receive a remote activation; stashed under the producer node id.
    RecvActivation { edge: usize, peer: usize, mb: usize },
    /// Ship the partial error (grad-layer payload, paper Eq. 6) back along
    /// a cross edge.
    SendError { edge: usize, peer: usize, mb: usize },
    /// Receive a partial error; accumulated into the producer's
    /// output-gradient.
    RecvError { edge: usize, peer: usize, mb: usize },
    /// Eager (MPI_Isend-style) activation send: initiate the transfer and
    /// continue immediately — never blocks, even on rendezvous transports.
    /// The payload aliases the stash and must stay live until the paired
    /// [`Instr::WaitSend`] with the same `handle` completes the send.
    PostSendActivation { edge: usize, peer: usize, mb: usize, handle: usize },
    /// Eager error send (see [`Instr::PostSendActivation`]). The error
    /// payload has no stash home, so the engine pins it in its in-flight
    /// table from post to wait.
    PostSendError { edge: usize, peer: usize, mb: usize, handle: usize },
    /// Complete the eager send `handle` (a per-rank id): on rendezvous
    /// transports this blocks until the matching receive has executed;
    /// the send buffer is released here.
    WaitSend { handle: usize },
    /// Microbatch `mb`'s backward is complete on this rank: its activation
    /// stash and gradient accumulators are dead. The memory model reads
    /// stash lifetime from (first `FwdCompute`/`RecvActivation`, this).
    DropStash { mb: usize },
    /// Average accumulated gradients over microbatches and allreduce
    /// across replicas (one fused call per partition communicator).
    AllreduceGrads,
    /// Apply the optimizer update.
    OptStep,
}

impl Instr {
    /// Message identity for the deadlock checkers and pairing verifier:
    /// (edge, mb, class) with class 0 = activation, 1 = error. Eager posts
    /// count as the send side of their message; `WaitSend` is a completion
    /// marker, not a message, and returns `None` like compute ops.
    fn msg_key(&self) -> Option<(usize, usize, u8, bool /*is_send*/, usize /*peer*/)> {
        match *self {
            Instr::SendActivation { edge, peer, mb }
            | Instr::PostSendActivation { edge, peer, mb, .. } => Some((edge, mb, 0, true, peer)),
            Instr::RecvActivation { edge, peer, mb } => Some((edge, mb, 0, false, peer)),
            Instr::SendError { edge, peer, mb }
            | Instr::PostSendError { edge, peer, mb, .. } => Some((edge, mb, 1, true, peer)),
            Instr::RecvError { edge, peer, mb } => Some((edge, mb, 1, false, peer)),
            _ => None,
        }
    }
}

/// Send-completion semantics for [`Program::check`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendSemantics {
    /// Synchronous (unbuffered) sends: a send completes only when the
    /// matching receive is at the head of the peer's program — the paper's
    /// §6.3 setting.
    Rendezvous,
    /// Buffered sends (MPI_Bsend — what the hfmpi fabric implements): a
    /// send completes immediately; a receive waits until the matching send
    /// has executed.
    Buffered,
}

/// How sends are expressed in the compiled program.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendMode {
    /// Blocking `SendActivation`/`SendError` ops (MPI_Send). Safe on
    /// buffered transports for every kind; rendezvous-safe only for GPipe.
    Blocking,
    /// Eager `PostSend*`/`WaitSend` pairs (MPI_Isend/MPI_Wait). Safe under
    /// both transport semantics for all four kinds; the send buffer stays
    /// live from post to wait.
    Eager,
}

/// A compiled per-rank instruction program for one training step.
#[derive(Clone, Debug)]
pub struct Program {
    pub kind: ScheduleKind,
    pub send_mode: SendMode,
    pub num_microbatches: usize,
    /// Pipeline ranks (processes) — one instruction stream each.
    pub num_partitions: usize,
    /// Stage-level partitions in the underlying `Partitioning`:
    /// `num_partitions * v` under interleaved, `num_partitions` otherwise.
    pub num_stages: usize,
    ranks: Vec<Vec<Instr>>,
}

impl Program {
    /// Compile the schedule for `(g, pt, m)` under `kind`. For
    /// [`ScheduleKind::Interleaved1F1B`] the partitioning is interpreted
    /// at *stage* level: `pt.num_partitions` must be a multiple of `v`
    /// (use [`ScheduleKind::partitioning`] to build it).
    pub fn compile(
        g: &ModelGraph,
        pt: &Partitioning,
        num_microbatches: usize,
        kind: ScheduleKind,
    ) -> Program {
        assert!(num_microbatches >= 1, "need at least one microbatch");
        let m = num_microbatches;
        if let ScheduleKind::Interleaved1F1B { v } = kind {
            if v > 1 {
                return interleaved::compile(g, pt, m, v);
            }
        }
        let p = pt.num_partitions;
        let mut ranks = Vec::with_capacity(p);
        for part in 0..p {
            let mut prog = vec![];
            match kind {
                ScheduleKind::GPipe => {
                    for mb in 0..m {
                        fwd_phase(pt, part, p, mb, &mut prog);
                    }
                    for mb in (0..m).rev() {
                        bwd_phase(g, pt, part, p, mb, false, true, &mut prog);
                    }
                }
                ScheduleKind::OneF1B | ScheduleKind::Interleaved1F1B { .. } => {
                    // Warmup depth: how many forwards stage `part` runs
                    // before its first backward. Bounds in-flight stashes
                    // to w+1 <= P - part.
                    let w = (p - 1 - part).min(m);
                    for mb in 0..w {
                        fwd_phase(pt, part, p, mb, &mut prog);
                    }
                    for k in 0..m - w {
                        fwd_phase(pt, part, p, w + k, &mut prog);
                        bwd_phase(g, pt, part, p, k, false, true, &mut prog);
                    }
                    for k in m - w..m {
                        bwd_phase(g, pt, part, p, k, false, true, &mut prog);
                    }
                }
                ScheduleKind::ZbH1 => {
                    // 1F1B skeleton with the backward split: `BwdInput`
                    // stays on the critical path; each microbatch's
                    // `BwdWeight` pass is deferred by d = w microbatches,
                    // landing the weight-grad work in what 1F1B leaves as
                    // drain bubble. Weight passes run microbatch-ascending,
                    // so gradient accumulation order matches 1F1B's and the
                    // P=1 degenerate is the sequential reference bitwise.
                    let w = (p - 1 - part).min(m);
                    for mb in 0..w {
                        fwd_phase(pt, part, p, mb, &mut prog);
                    }
                    for k in 0..m {
                        if w + k < m {
                            fwd_phase(pt, part, p, w + k, &mut prog);
                        }
                        bwd_phase(g, pt, part, p, k, true, true, &mut prog);
                        if k >= w {
                            bwd_weight_phase(g, pt, part, k - w, &mut prog);
                        }
                    }
                    // Flush the deferred weight-grad passes — the epilogue
                    // (AllreduceGrads) runs only after the last BwdWeight.
                    for mb in m - w..m {
                        bwd_weight_phase(g, pt, part, mb, &mut prog);
                    }
                }
            }
            prog.push(Instr::AllreduceGrads);
            prog.push(Instr::OptStep);
            ranks.push(prog);
        }
        Program {
            kind,
            send_mode: SendMode::Blocking,
            num_microbatches: m,
            num_partitions: p,
            num_stages: p,
            ranks,
        }
    }

    /// [`Program::compile`] plus a send-mode axis: `SendMode::Blocking`
    /// returns the classic program unchanged; `SendMode::Eager` rewrites
    /// every blocking send into a `PostSend*`/`WaitSend` pair (see
    /// [`Program::into_eager`]), making the program deadlock-free under
    /// rendezvous semantics for all four kinds.
    pub fn compile_with(
        g: &ModelGraph,
        pt: &Partitioning,
        num_microbatches: usize,
        kind: ScheduleKind,
        mode: SendMode,
    ) -> Program {
        let prog = Self::compile(g, pt, num_microbatches, kind);
        match mode {
            SendMode::Blocking => prog,
            SendMode::Eager => prog.into_eager(),
        }
    }

    /// Rewrite blocking sends into eager post/wait pairs. Each
    /// `SendActivation`/`SendError` becomes the matching `PostSend*` with a
    /// fresh per-rank handle; the paired `WaitSend` is placed at the end of
    /// the payload's live interval — immediately before the microbatch's
    /// `DropStash` (where its stash dies) — and any handle still open at
    /// `AllreduceGrads` or at stream end is flushed there. Waits never
    /// deadlock: a posted send never blocks its receiver's progress, and by
    /// the time a rank reaches `DropStash { mb }` every downstream consumer
    /// of that microbatch has already executed the matching receive (its
    /// own backward of `mb` precedes ours in pipeline order) — verified
    /// under [`SendSemantics::Rendezvous`] per kind x random topology x m
    /// by the conformance harness.
    pub fn into_eager(mut self) -> Program {
        for prog in &mut self.ranks {
            let mut out = Vec::with_capacity(prog.len() + 8);
            let mut next_handle = 0usize;
            // Posted but not yet waited handles, with their microbatch.
            let mut open: Vec<(usize, usize)> = vec![];
            for &instr in prog.iter() {
                match instr {
                    Instr::SendActivation { edge, peer, mb } => {
                        out.push(Instr::PostSendActivation { edge, peer, mb, handle: next_handle });
                        open.push((next_handle, mb));
                        next_handle += 1;
                    }
                    Instr::SendError { edge, peer, mb } => {
                        out.push(Instr::PostSendError { edge, peer, mb, handle: next_handle });
                        open.push((next_handle, mb));
                        next_handle += 1;
                    }
                    Instr::DropStash { mb } => {
                        // The microbatch's buffers die here: complete all
                        // of its in-flight sends first.
                        open.retain(|&(handle, b)| {
                            if b == mb {
                                out.push(Instr::WaitSend { handle });
                                false
                            } else {
                                true
                            }
                        });
                        out.push(instr);
                    }
                    Instr::AllreduceGrads => {
                        for (handle, _) in open.drain(..) {
                            out.push(Instr::WaitSend { handle });
                        }
                        out.push(instr);
                    }
                    other => out.push(other),
                }
            }
            for (handle, _) in open.drain(..) {
                out.push(Instr::WaitSend { handle });
            }
            *prog = out;
        }
        self.send_mode = SendMode::Eager;
        self
    }

    /// Map each eager-send handle of `rank` to its message identity
    /// `(edge, mb, class)` — used by the rendezvous checker and the
    /// simulator to resolve `WaitSend { handle }`.
    pub fn handle_keys(&self, rank: usize) -> HashMap<usize, (usize, usize, u8)> {
        self.ranks[rank]
            .iter()
            .filter_map(|i| match *i {
                Instr::PostSendActivation { edge, mb, handle, .. } => Some((handle, (edge, mb, 0))),
                Instr::PostSendError { edge, mb, handle, .. } => Some((handle, (edge, mb, 1))),
                _ => None,
            })
            .collect()
    }

    /// A forward-only single-microbatch program (evaluation path). Under
    /// interleaved kinds each rank visits its chunks in ascending stage
    /// order, which is deadlock-free on the buffered fabric.
    pub fn forward_only(pt: &Partitioning, kind: ScheduleKind) -> Program {
        let v = kind.virtual_stages();
        let stages = pt.num_partitions;
        assert_eq!(stages % v, 0, "stage count {stages} not divisible by v={v}");
        let p = stages / v;
        let mut ranks = Vec::with_capacity(p);
        for rank in 0..p {
            let mut prog = vec![];
            for c in 0..v {
                fwd_phase(pt, c * p + rank, p, 0, &mut prog);
            }
            ranks.push(prog);
        }
        Program {
            kind,
            send_mode: SendMode::Blocking,
            num_microbatches: 1,
            num_partitions: p,
            num_stages: stages,
            ranks,
        }
    }

    /// The instruction stream of one rank.
    pub fn rank(&self, rank: usize) -> &[Instr] {
        &self.ranks[rank]
    }

    /// The stage indices rank `rank` executes, ascending (chunk 0 first).
    pub fn stages_of(&self, rank: usize) -> Vec<usize> {
        (rank..self.num_stages).step_by(self.num_partitions).collect()
    }

    /// Peak number of microbatch stashes simultaneously live on `rank`,
    /// from the program's own live intervals (first touch -> `DropStash`).
    /// GPipe yields `m`; 1F1B and ZB-H1 yield `min(P - rank, m)`;
    /// interleaved at most `min(2P, m)` (warmup spans two microbatch
    /// groups).
    pub fn peak_resident_microbatches(&self, rank: usize) -> usize {
        let mut touched: Vec<bool> = vec![false; self.num_microbatches];
        let mut live = 0usize;
        let mut peak = 0usize;
        for instr in &self.ranks[rank] {
            match *instr {
                Instr::FwdCompute { mb, .. } | Instr::RecvActivation { mb, .. } => {
                    if !touched[mb] {
                        touched[mb] = true;
                        live += 1;
                        peak = peak.max(live);
                    }
                }
                Instr::DropStash { mb } => {
                    if touched[mb] {
                        touched[mb] = false;
                        live -= 1;
                    }
                }
                _ => {}
            }
        }
        peak
    }

    /// Worst peak residency across all ranks.
    pub fn max_peak_resident_microbatches(&self) -> usize {
        (0..self.num_partitions)
            .map(|p| self.peak_resident_microbatches(p))
            .max()
            .unwrap_or(0)
    }

    /// Peak bytes of stashed activations on `rank` for microbatch size
    /// `mb`, byte-accurate from the instruction stream: each `FwdCompute`
    /// makes its node's output live (own nodes only — received activations
    /// are not counted, matching `mem::partition_memory`'s accounting),
    /// and `DropStash` retires the microbatch. Eager error sends pin their
    /// payload (the producer's output-shaped gradient) from
    /// `PostSendError` to the matching `WaitSend` — that in-flight buffer
    /// is counted too; eager *activation* posts alias the stash, which is
    /// already live until `DropStash`, so they add nothing. For flat
    /// blocking schedules this equals
    /// `peak_resident_microbatches * Σ node bytes`; under interleaved the
    /// chunks of one rank hold different byte totals, so this walk is the
    /// ground truth the memory model reads.
    pub fn peak_activation_bytes(
        &self,
        g: &ModelGraph,
        pt: &Partitioning,
        rank: usize,
        mb: usize,
    ) -> u64 {
        let mut live: HashMap<(usize, NodeId), u64> = HashMap::new();
        let mut in_flight_err: HashMap<usize, u64> = HashMap::new();
        let (mut cur, mut peak) = (0u64, 0u64);
        for instr in &self.ranks[rank] {
            match *instr {
                Instr::FwdCompute { node, mb: b, .. } => {
                    let bytes =
                        g.nodes[node].out_shape.iter().product::<usize>() as u64 * 4 * mb as u64;
                    if live.insert((b, node), bytes).is_none() {
                        cur += bytes;
                        peak = peak.max(cur);
                    }
                }
                Instr::PostSendError { edge, handle, .. } => {
                    let src = pt.edges[edge].src_node;
                    let bytes =
                        g.nodes[src].out_shape.iter().product::<usize>() as u64 * 4 * mb as u64;
                    in_flight_err.insert(handle, bytes);
                    cur += bytes;
                    peak = peak.max(cur);
                }
                Instr::WaitSend { handle } => {
                    if let Some(bytes) = in_flight_err.remove(&handle) {
                        cur -= bytes;
                    }
                }
                Instr::DropStash { mb: b } => {
                    live.retain(|&(bb, _), bytes| {
                        if bb == b {
                            cur -= *bytes;
                            false
                        } else {
                            true
                        }
                    });
                }
                _ => {}
            }
        }
        peak
    }

    /// Simulate the program's message ops under the given send semantics.
    /// Returns `Ok(matched message pairs)` if every rank completes, or
    /// `Err(stuck rank ids)` on deadlock. Compute/stash/epilogue ops never
    /// block. Blocking sends complete only head-to-head against the
    /// matching receive under [`SendSemantics::Rendezvous`]; eager posts
    /// never block under either semantics, and `WaitSend` blocks (under
    /// rendezvous) until the posted message's receive has executed.
    pub fn check(&self, sem: SendSemantics) -> Result<usize, Vec<usize>> {
        use std::collections::HashSet;
        let p = self.ranks.len();
        let keys: Vec<HashMap<usize, (usize, usize, u8)>> =
            (0..p).map(|r| self.handle_keys(r)).collect();
        let mut pc = vec![0usize; p];
        let mut steps = 0usize;
        match sem {
            SendSemantics::Rendezvous => {
                // posted[(edge, mb, class)] = eager sends not yet received;
                // recv_done = messages whose receive has executed (what a
                // WaitSend unblocks on).
                let mut posted: HashMap<(usize, usize, u8), usize> = HashMap::new();
                let mut recv_done: HashSet<(usize, usize, u8)> = HashSet::new();
                loop {
                    let mut progressed = false;
                    for a in 0..p {
                        while pc[a] < self.ranks[a].len() {
                            let instr = self.ranks[a][pc[a]];
                            match instr {
                                Instr::PostSendActivation { edge, mb, .. } => {
                                    *posted.entry((edge, mb, 0)).or_insert(0) += 1;
                                }
                                Instr::PostSendError { edge, mb, .. } => {
                                    *posted.entry((edge, mb, 1)).or_insert(0) += 1;
                                }
                                Instr::WaitSend { handle } => {
                                    let key = keys[a][&handle];
                                    if !recv_done.contains(&key) {
                                        break; // receive not yet executed
                                    }
                                }
                                _ => match instr.msg_key() {
                                    None => {}
                                    Some((edge, mb, class, true, peer)) => {
                                        // Blocking send: completes only when
                                        // the matching receive is at the head
                                        // of the peer's program.
                                        let facing = self.ranks[peer].get(pc[peer]).and_then(
                                            Instr::msg_key,
                                        ) == Some((edge, mb, class, false, a));
                                        if !facing {
                                            break;
                                        }
                                        pc[peer] += 1;
                                        recv_done.insert((edge, mb, class));
                                        steps += 1;
                                    }
                                    Some((edge, mb, class, false, peer)) => {
                                        let key = (edge, mb, class);
                                        if let Some(n) =
                                            posted.get_mut(&key).filter(|n| **n > 0)
                                        {
                                            // An eager post satisfies the
                                            // receive without rank sync.
                                            *n -= 1;
                                            recv_done.insert(key);
                                            steps += 1;
                                        } else if self.ranks[peer]
                                            .get(pc[peer])
                                            .and_then(Instr::msg_key)
                                            == Some((edge, mb, class, true, a))
                                        {
                                            // Facing blocking send: complete
                                            // both sides.
                                            pc[peer] += 1;
                                            recv_done.insert(key);
                                            steps += 1;
                                        } else {
                                            break;
                                        }
                                    }
                                },
                            }
                            pc[a] += 1;
                            progressed = true;
                        }
                    }
                    if (0..p).all(|r| pc[r] >= self.ranks[r].len()) {
                        return Ok(steps);
                    }
                    if !progressed {
                        return Err((0..p).filter(|&r| pc[r] < self.ranks[r].len()).collect());
                    }
                }
            }
            SendSemantics::Buffered => {
                // sent[(edge, mb, class)] = completed sends not yet received.
                // Eager posts behave exactly like blocking sends (both
                // complete immediately) and waits never block.
                let mut sent: HashMap<(usize, usize, u8), usize> = HashMap::new();
                loop {
                    let mut progressed = false;
                    for a in 0..p {
                        while pc[a] < self.ranks[a].len() {
                            match self.ranks[a][pc[a]].msg_key() {
                                None => {}
                                Some((edge, mb, class, true, _peer)) => {
                                    *sent.entry((edge, mb, class)).or_insert(0) += 1;
                                }
                                Some((edge, mb, class, false, _peer)) => {
                                    let slot = sent.entry((edge, mb, class)).or_insert(0);
                                    if *slot == 0 {
                                        break; // blocked on a send not yet issued
                                    }
                                    *slot -= 1;
                                    steps += 1;
                                }
                            }
                            pc[a] += 1;
                            progressed = true;
                        }
                    }
                    if (0..p).all(|r| pc[r] >= self.ranks[r].len()) {
                        return Ok(steps);
                    }
                    if !progressed {
                        return Err((0..p).filter(|&r| pc[r] < self.ranks[r].len()).collect());
                    }
                }
            }
        }
    }

    /// Machine-check exactly-once, peer-consistent, order-consistent
    /// message pairing across the rank streams: every `(edge, mb, class)`
    /// has exactly one send and one receive, each naming the other's rank
    /// as its peer (never itself), and for every `(edge, class)` channel
    /// both endpoints see the microbatches in the same order — the fabric
    /// delivers per-tag FIFO, so mismatched order would swap payloads.
    pub fn verify_message_pairing(&self) -> anyhow::Result<()> {
        use std::collections::BTreeMap;
        type Key = (usize, usize, u8);
        let mut sends: BTreeMap<Key, Vec<(usize, usize)>> = BTreeMap::new();
        let mut recvs: BTreeMap<Key, Vec<(usize, usize)>> = BTreeMap::new();
        let mut send_order: BTreeMap<(usize, u8), Vec<usize>> = BTreeMap::new();
        let mut recv_order: BTreeMap<(usize, u8), Vec<usize>> = BTreeMap::new();
        for rank in 0..self.num_partitions {
            for i in &self.ranks[rank] {
                if let Some((edge, mb, class, is_send, peer)) = i.msg_key() {
                    if is_send {
                        sends.entry((edge, mb, class)).or_default().push((rank, peer));
                        send_order.entry((edge, class)).or_default().push(mb);
                    } else {
                        recvs.entry((edge, mb, class)).or_default().push((rank, peer));
                        recv_order.entry((edge, class)).or_default().push(mb);
                    }
                }
            }
        }
        for (k, s) in &sends {
            anyhow::ensure!(s.len() == 1, "message {k:?} sent {} times", s.len());
            let r = recvs
                .get(k)
                .ok_or_else(|| anyhow::anyhow!("message {k:?} sent but never received"))?;
            anyhow::ensure!(r.len() == 1, "message {k:?} received {} times", r.len());
            let ((sr, sp), (rr, rp)) = (s[0], r[0]);
            anyhow::ensure!(
                sp == rr && rp == sr,
                "message {k:?}: send {sr}->{sp} does not face recv on {rr} from {rp}"
            );
            anyhow::ensure!(sr != rr, "message {k:?} is a self-send on rank {sr}");
        }
        for k in recvs.keys() {
            anyhow::ensure!(sends.contains_key(k), "message {k:?} received but never sent");
        }
        for (k, so) in &send_order {
            let ro = recv_order.get(k).expect("recv channel exists if send channel does");
            anyhow::ensure!(
                so == ro,
                "channel {k:?}: send mb order {so:?} != recv mb order {ro:?}"
            );
        }
        Ok(())
    }

    /// Machine-check exactly-once Post→Wait pairing per rank: every handle
    /// is posted exactly once and waited exactly once, the wait comes after
    /// its post, no wait names an unposted handle (orphan), and no handle
    /// is waited twice. Blocking programs (no eager ops) pass trivially.
    pub fn verify_eager_pairing(&self) -> anyhow::Result<()> {
        for rank in 0..self.num_partitions {
            // handle -> already waited?
            let mut open: HashMap<usize, bool> = HashMap::new();
            for i in &self.ranks[rank] {
                match *i {
                    Instr::PostSendActivation { handle, .. }
                    | Instr::PostSendError { handle, .. } => {
                        anyhow::ensure!(
                            open.insert(handle, false).is_none(),
                            "rank {rank}: handle {handle} posted twice"
                        );
                    }
                    Instr::WaitSend { handle } => match open.get_mut(&handle) {
                        None => anyhow::bail!(
                            "rank {rank}: WaitSend on handle {handle} that was never posted \
                             (orphan wait, or wait precedes its post)"
                        ),
                        Some(waited @ false) => *waited = true,
                        Some(true) => {
                            anyhow::bail!("rank {rank}: handle {handle} waited twice")
                        }
                    },
                    _ => {}
                }
            }
            for (handle, waited) in open {
                anyhow::ensure!(
                    waited,
                    "rank {rank}: handle {handle} posted but never waited \
                     (orphaned in-flight send buffer)"
                );
            }
        }
        Ok(())
    }

    /// Peak number of eager sends simultaneously in flight (posted, not
    /// yet waited) on `rank`. Zero for blocking programs. The engine's
    /// `CommEngine` budget-checks this against the message-tag space.
    pub fn peak_in_flight_sends(&self, rank: usize) -> usize {
        let (mut live, mut peak) = (0usize, 0usize);
        for i in &self.ranks[rank] {
            match i {
                Instr::PostSendActivation { .. } | Instr::PostSendError { .. } => {
                    live += 1;
                    peak = peak.max(live);
                }
                Instr::WaitSend { .. } => live = live.saturating_sub(1),
                _ => {}
            }
        }
        peak
    }

    /// Worst peak in-flight eager-send count across all ranks.
    pub fn max_in_flight_sends(&self) -> usize {
        (0..self.num_partitions).map(|p| self.peak_in_flight_sends(p)).max().unwrap_or(0)
    }
}

/// Forward phase of one microbatch on one stage: message ops in the §6.3
/// global order `(consumer node, producer node)` — the same linearization
/// `partition::MsgSchedule::build` produces — with `FwdCompute` ops
/// inserted at their dependency-minimal slots (a node's compute goes after
/// all messages keyed below it, so its receives precede it and its sends
/// follow it). `ranks` maps stages onto ranks (`stage % ranks`); messages
/// between two stages of the same rank are elided, because the producer's
/// activation is already in the rank's stash: for the same microbatch a
/// lower chunk's forward always precedes a higher chunk's on one rank.
fn fwd_phase(pt: &Partitioning, stage: usize, ranks: usize, mb: usize, out: &mut Vec<Instr>) {
    let my_rank = stage % ranks;
    let mut msgs: Vec<(usize, usize, Instr)> = vec![];
    for e in &pt.edges {
        if e.src_part == stage && e.dst_part % ranks != my_rank {
            msgs.push((
                e.dst_node,
                e.src_node,
                Instr::SendActivation { edge: e.id, peer: e.dst_part % ranks, mb },
            ));
        }
        if e.dst_part == stage && e.src_part % ranks != my_rank {
            msgs.push((
                e.dst_node,
                e.src_node,
                Instr::RecvActivation { edge: e.id, peer: e.src_part % ranks, mb },
            ));
        }
    }
    msgs.sort_by_key(|&(d, s, _)| (d, s));
    let nodes = &pt.parts[stage];
    let mut ni = 0usize;
    for (d, _s, m) in msgs {
        // Every local node strictly below the message key is computable
        // now; in particular a send's producer (s < d) and not yet the
        // receive's consumer (== d).
        while ni < nodes.len() && nodes[ni] < d {
            out.push(Instr::FwdCompute { node: nodes[ni], stage, mb });
            ni += 1;
        }
        out.push(m);
    }
    while ni < nodes.len() {
        out.push(Instr::FwdCompute { node: nodes[ni], stage, mb });
        ni += 1;
    }
}

/// Backward phase of one microbatch on one stage: the mirror
/// linearization, keyed `(Reverse(producer), Reverse(consumer))`, with
/// backward compute ops interleaved in reverse topological order. With
/// `split` set, parameter-carrying nodes emit `BwdInput` (ZB-H1) instead
/// of the fused `BwdCompute`; parameter-less nodes have no weight half and
/// always emit `BwdCompute`. `drop` appends the `DropStash` marker — the
/// caller sets it on the microbatch's *last* backward phase on this rank
/// (chunk 0 under interleaved). Same-rank messages are elided as in
/// [`fwd_phase`]: a higher chunk's backward precedes a lower chunk's, so
/// the error is accumulated into the rank-local `gout` directly.
#[allow(clippy::too_many_arguments)]
fn bwd_phase(
    g: &ModelGraph,
    pt: &Partitioning,
    stage: usize,
    ranks: usize,
    mb: usize,
    split: bool,
    drop: bool,
    out: &mut Vec<Instr>,
) {
    let my_rank = stage % ranks;
    let mut msgs: Vec<(usize, usize, Instr)> = vec![];
    for e in &pt.edges {
        if e.dst_part == stage && e.src_part % ranks != my_rank {
            msgs.push((
                e.src_node,
                e.dst_node,
                Instr::SendError { edge: e.id, peer: e.src_part % ranks, mb },
            ));
        }
        if e.src_part == stage && e.dst_part % ranks != my_rank {
            msgs.push((
                e.src_node,
                e.dst_node,
                Instr::RecvError { edge: e.id, peer: e.dst_part % ranks, mb },
            ));
        }
    }
    msgs.sort_by_key(|&(s, d, _)| (std::cmp::Reverse(s), std::cmp::Reverse(d)));
    let nodes = &pt.parts[stage];
    let mut ni = 0usize; // index into nodes traversed in reverse
    let rev = |i: usize| nodes[nodes.len() - 1 - i];
    let mut emit = |node: NodeId, out: &mut Vec<Instr>| {
        if matches!(g.nodes[node].kind, LayerKind::Input) {
            return;
        }
        if split && !g.nodes[node].params.is_empty() {
            out.push(Instr::BwdInput { node, stage, mb });
        } else {
            out.push(Instr::BwdCompute { node, stage, mb });
        }
    };
    for (s, _d, m) in msgs {
        // Every local node strictly above the producer key runs its
        // backward now; in particular an error-send's consumer (d > s) and
        // not yet the error-receive's producer (== s).
        while ni < nodes.len() && rev(ni) > s {
            emit(rev(ni), out);
            ni += 1;
        }
        out.push(m);
    }
    while ni < nodes.len() {
        emit(rev(ni), out);
        ni += 1;
    }
    if drop {
        out.push(Instr::DropStash { mb });
    }
}

/// ZB-H1 weight-grad pass: retire the parked parameter gradients of one
/// microbatch on one stage, reverse topological order (mirroring the
/// fused backward's accumulation order).
fn bwd_weight_phase(
    g: &ModelGraph,
    pt: &Partitioning,
    stage: usize,
    mb: usize,
    out: &mut Vec<Instr>,
) {
    for &node in pt.parts[stage].iter().rev() {
        if !g.nodes[node].params.is_empty() {
            out.push(Instr::BwdWeight { node, stage, mb });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn program(parts: usize, m: usize, kind: ScheduleKind) -> (Partitioning, Program) {
        let g = zoo::resnet20_v1();
        let pt = kind.partitioning(&g, parts).unwrap();
        let prog = Program::compile(&g, &pt, m, kind);
        (pt, prog)
    }

    #[test]
    fn gpipe_is_rendezvous_safe_and_covers_all_edges() {
        let (pt, prog) = program(4, 3, ScheduleKind::GPipe);
        let steps = prog.check(SendSemantics::Rendezvous).unwrap();
        assert_eq!(steps, pt.edges.len() * 2 * 3, "act+err per edge per mb");
        // Buffered semantics can only be more permissive.
        assert_eq!(prog.check(SendSemantics::Buffered).unwrap(), steps);
    }

    #[test]
    fn one_f1b_passes_buffered_check() {
        let (pt, prog) = program(4, 8, ScheduleKind::OneF1B);
        let steps = prog.check(SendSemantics::Buffered).unwrap();
        assert_eq!(steps, pt.edges.len() * 2 * 8);
    }

    #[test]
    fn one_f1b_needs_buffered_sends() {
        // The documented limitation: *blocking* 1F1B over >1 stage
        // deadlocks under rendezvous semantics (facing sends), which is
        // why pipelined systems use buffered/asynchronous communication.
        // If this ever starts passing, the generator changed — revisit the
        // module docs. The eager rewrite of the same program is the fix.
        let (_, prog) = program(3, 6, ScheduleKind::OneF1B);
        assert!(prog.check(SendSemantics::Rendezvous).is_err());
        assert!(prog.clone().into_eager().check(SendSemantics::Rendezvous).is_ok());
    }

    #[test]
    fn eager_programs_pass_both_semantics_for_all_kinds() {
        for kind in [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B,
            ScheduleKind::Interleaved1F1B { v: 2 },
            ScheduleKind::ZbH1,
        ] {
            let g = zoo::resnet20_v1();
            let pt = kind.partitioning(&g, 3).unwrap();
            let prog = Program::compile_with(&g, &pt, 6, kind, SendMode::Eager);
            assert_eq!(prog.send_mode, SendMode::Eager);
            let r = prog
                .check(SendSemantics::Rendezvous)
                .unwrap_or_else(|stuck| panic!("{kind:?}: stuck ranks {stuck:?}"));
            assert_eq!(prog.check(SendSemantics::Buffered).unwrap(), r);
            prog.verify_message_pairing().unwrap();
            prog.verify_eager_pairing().unwrap();
        }
    }

    #[test]
    fn eager_rewrite_replaces_every_blocking_send_and_pairs_waits() {
        let (_, blocking) = program(4, 8, ScheduleKind::OneF1B);
        let eager = blocking.clone().into_eager();
        for rank in 0..4 {
            assert!(
                !eager.rank(rank).iter().any(|i| matches!(
                    i,
                    Instr::SendActivation { .. } | Instr::SendError { .. }
                )),
                "rank {rank}: blocking send survived the eager rewrite"
            );
            // Same messages, same per-channel order as the blocking stream.
            let keys = |p: &Program| -> Vec<_> {
                p.rank(rank).iter().filter_map(Instr::msg_key).collect()
            };
            assert_eq!(keys(&blocking), keys(&eager), "rank {rank}");
            // Waits sit at the end of the payload's live interval: no eager
            // handle may still be open after AllreduceGrads.
            let ar = eager
                .rank(rank)
                .iter()
                .position(|i| matches!(i, Instr::AllreduceGrads))
                .unwrap();
            let posts = eager.rank(rank)[..ar]
                .iter()
                .filter(|i| {
                    matches!(i, Instr::PostSendActivation { .. } | Instr::PostSendError { .. })
                })
                .count();
            let waits = eager.rank(rank)[..ar]
                .iter()
                .filter(|i| matches!(i, Instr::WaitSend { .. }))
                .count();
            assert_eq!(posts, waits, "rank {rank}: open handles past AllreduceGrads");
        }
        eager.verify_eager_pairing().unwrap();
    }

    #[test]
    fn eager_pairing_verifier_catches_orphans_and_double_waits() {
        let (_, prog) = program(2, 2, ScheduleKind::OneF1B);
        let mut eager = prog.into_eager();
        assert!(eager.verify_eager_pairing().is_ok());
        // Orphan wait (handle never posted).
        let mut broken = eager.clone();
        broken.ranks[0].push(Instr::WaitSend { handle: 999 });
        assert!(broken.verify_eager_pairing().is_err());
        // Dropped wait (posted but never completed).
        let wait_at =
            eager.ranks[0].iter().position(|i| matches!(i, Instr::WaitSend { .. })).unwrap();
        let dropped = eager.ranks[0].remove(wait_at);
        assert!(eager.verify_eager_pairing().is_err());
        // Double wait.
        eager.ranks[0].insert(wait_at, dropped);
        eager.ranks[0].push(dropped);
        assert!(eager.verify_eager_pairing().is_err());
    }

    #[test]
    fn in_flight_sends_are_bounded_and_nonzero_for_eager_pipelines() {
        let (_, blocking) = program(4, 8, ScheduleKind::OneF1B);
        assert_eq!(blocking.max_in_flight_sends(), 0);
        let eager = blocking.into_eager();
        let peak = eager.max_in_flight_sends();
        assert!(peak >= 1, "a pipelined eager program keeps sends in flight");
        // Each in-flight send occupies a distinct (edge, mb, class) tag, so
        // the peak can never exceed the per-rank tag space.
        for rank in 0..4 {
            let channels: usize = {
                use std::collections::HashSet;
                eager
                    .rank(rank)
                    .iter()
                    .filter_map(|i| {
                        i.msg_key().filter(|&(_, _, _, s, _)| s).map(|(e, _, c, _, _)| (e, c))
                    })
                    .collect::<HashSet<_>>()
                    .len()
            };
            assert!(
                eager.peak_in_flight_sends(rank)
                    <= channels * eager.peak_resident_microbatches(rank).max(1),
                "rank {rank}"
            );
        }
    }

    #[test]
    fn eager_error_buffers_count_toward_peak_memory() {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, 3).unwrap();
        let blocking = Program::compile(&g, &pt, 6, ScheduleKind::OneF1B);
        let eager = blocking.clone().into_eager();
        for rank in 0..3 {
            let b = blocking.peak_activation_bytes(&g, &pt, rank, 4);
            let e = eager.peak_activation_bytes(&g, &pt, rank, 4);
            assert!(e >= b, "rank {rank}: eager accounting lost bytes ({e} < {b})");
        }
        // Some rank must actually pin an error buffer across a gap.
        assert!(
            (0..3).any(|r| eager.peak_activation_bytes(&g, &pt, r, 4)
                > blocking.peak_activation_bytes(&g, &pt, r, 4)),
            "no in-flight error buffer was ever counted"
        );
    }

    #[test]
    fn gpipe_residency_is_m() {
        let (_, prog) = program(4, 6, ScheduleKind::GPipe);
        for part in 0..4 {
            assert_eq!(prog.peak_resident_microbatches(part), 6);
        }
    }

    #[test]
    fn one_f1b_residency_bounded_by_depth() {
        let (_, prog) = program(4, 16, ScheduleKind::OneF1B);
        for part in 0..4 {
            assert_eq!(prog.peak_resident_microbatches(part), 4 - part);
        }
        // And never exceeds m when the pipeline is shallow vs m.
        let (_, small) = program(4, 2, ScheduleKind::OneF1B);
        assert!(small.max_peak_resident_microbatches() <= 2);
    }

    #[test]
    fn zb_h1_passes_buffered_check_and_covers_all_edges() {
        let (pt, prog) = program(4, 8, ScheduleKind::ZbH1);
        let steps = prog.check(SendSemantics::Buffered).unwrap();
        assert_eq!(steps, pt.edges.len() * 2 * 8);
        prog.verify_message_pairing().unwrap();
    }

    #[test]
    fn zb_h1_residency_matches_one_f1b() {
        // The split backward moves weight-grad work, not stash lifetimes:
        // DropStash still follows the input-grad pass, so the activation
        // bound is 1F1B's min(P - rank, m). (The deferred weight passes
        // park only parameter-gradient tensors, not activations.)
        let (_, prog) = program(4, 16, ScheduleKind::ZbH1);
        for part in 0..4 {
            assert_eq!(prog.peak_resident_microbatches(part), 4 - part);
        }
    }

    #[test]
    fn zb_h1_defers_weight_work_into_the_drain() {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let m = 8;
        let prog = Program::compile(&g, &pt, m, ScheduleKind::ZbH1);
        for part in 0..4 {
            let stream = prog.rank(part);
            let w = (4 - 1 - part).min(m);
            // Exactly one BwdInput and one BwdWeight per (param node, mb),
            // weight passes microbatch-ascending and deferred by w.
            let weights: Vec<usize> = stream
                .iter()
                .filter_map(|i| match i {
                    Instr::BwdWeight { mb, .. } => Some(*mb),
                    _ => None,
                })
                .collect();
            let param_nodes =
                pt.parts[part].iter().filter(|&&n| !g.nodes[n].params.is_empty()).count();
            assert_eq!(weights.len(), param_nodes * m);
            let mut sorted = weights.clone();
            sorted.sort();
            assert_eq!(weights, sorted, "rank {part}: weight passes must ascend");
            // The mb-k weight pass comes after the mb-(k+w) input pass
            // (deferral window) and the epilogue after the last weight op.
            let pos_last_w = stream
                .iter()
                .rposition(|i| matches!(i, Instr::BwdWeight { .. }))
                .unwrap();
            let pos_ar = stream
                .iter()
                .position(|i| matches!(i, Instr::AllreduceGrads))
                .unwrap();
            assert!(pos_last_w < pos_ar, "rank {part}: allreduce before last BwdWeight");
            if w > 0 {
                let first_w = stream
                    .iter()
                    .position(|i| matches!(i, Instr::BwdWeight { .. }))
                    .unwrap();
                let bi_w = stream
                    .iter()
                    .position(|i| {
                        matches!(
                            i,
                            Instr::BwdInput { mb, .. } | Instr::BwdCompute { mb, .. } if *mb == w
                        )
                    })
                    .unwrap();
                assert!(first_w > bi_w, "rank {part}: weight pass not deferred");
            }
        }
    }

    #[test]
    fn zb_h1_single_rank_degenerates_to_ascending_f_bi_w() {
        let g = zoo::mlp(8, &[8, 8], 4);
        let pt = Partitioning::auto(&g, 1).unwrap();
        let prog = Program::compile(&g, &pt, 3, ScheduleKind::ZbH1);
        let mut seen = vec![];
        for i in prog.rank(0) {
            match *i {
                Instr::FwdCompute { mb, node, .. } if node == 0 => seen.push(('f', mb)),
                Instr::DropStash { mb } => seen.push(('d', mb)),
                Instr::BwdWeight { mb, node, .. } if node == 1 => seen.push(('w', mb)),
                _ => {}
            }
        }
        assert_eq!(
            seen,
            vec![
                ('f', 0),
                ('d', 0),
                ('w', 0),
                ('f', 1),
                ('d', 1),
                ('w', 1),
                ('f', 2),
                ('d', 2),
                ('w', 2)
            ]
        );
    }

    #[test]
    fn interleaved_passes_buffered_check_and_pairing() {
        for (ranks, v, m) in [(2, 2, 4), (2, 2, 3), (4, 2, 8), (2, 3, 5), (3, 2, 7)] {
            let g = zoo::resnet56_v1();
            let kind = ScheduleKind::Interleaved1F1B { v };
            let pt = kind.partitioning(&g, ranks).unwrap();
            let prog = Program::compile(&g, &pt, m, kind);
            assert_eq!(prog.num_partitions, ranks);
            assert_eq!(prog.num_stages, ranks * v);
            prog.check(SendSemantics::Buffered)
                .unwrap_or_else(|stuck| panic!("R={ranks} v={v} m={m}: stuck ranks {stuck:?}"));
            prog.verify_message_pairing().unwrap();
        }
    }

    #[test]
    fn interleaved_maps_stages_round_robin() {
        let (pt, prog) = program(2, 4, ScheduleKind::Interleaved1F1B { v: 2 });
        assert_eq!(pt.num_partitions, 4, "stage-level partitioning");
        for rank in 0..2 {
            assert_eq!(prog.stages_of(rank), vec![rank, rank + 2]);
            for i in prog.rank(rank) {
                if let Instr::FwdCompute { stage, node, .. }
                | Instr::BwdCompute { stage, node, .. } = *i
                {
                    assert_eq!(stage % 2, rank);
                    assert!(pt.parts[stage].contains(&node));
                }
            }
        }
    }

    #[test]
    fn interleaved_elides_same_rank_messages() {
        // Every message op in an interleaved program crosses ranks; edges
        // between two stages of the same rank produce no send/recv.
        let g = zoo::resnet56_v1();
        let kind = ScheduleKind::Interleaved1F1B { v: 2 };
        let pt = kind.partitioning(&g, 2).unwrap();
        let prog = Program::compile(&g, &pt, 4, kind);
        let cross: usize =
            pt.edges.iter().filter(|e| e.src_part % 2 != e.dst_part % 2).count();
        let steps = prog.check(SendSemantics::Buffered).unwrap();
        assert_eq!(steps, cross * 2 * 4, "only cross-rank edges carry messages");
        for e in pt.edges.iter().filter(|e| e.src_part % 2 == e.dst_part % 2) {
            for rank in 0..2 {
                assert!(
                    !prog.rank(rank).iter().any(|i| matches!(
                        i.msg_key(),
                        Some((edge, _, _, _, _)) if edge == e.id
                    )),
                    "same-rank edge {} must be elided",
                    e.id
                );
            }
        }
    }

    #[test]
    fn single_partition_one_f1b_interleaves() {
        // P=1 degenerates to fwd/bwd per microbatch, ascending.
        let g = zoo::mlp(8, &[8, 8], 4);
        let pt = Partitioning::auto(&g, 1).unwrap();
        let prog = Program::compile(&g, &pt, 3, ScheduleKind::OneF1B);
        let mut seen = vec![];
        for i in prog.rank(0) {
            match *i {
                Instr::FwdCompute { mb, node, .. } if node == 0 => seen.push(('f', mb)),
                Instr::DropStash { mb } => seen.push(('d', mb)),
                _ => {}
            }
        }
        assert_eq!(seen, vec![('f', 0), ('d', 0), ('f', 1), ('d', 1), ('f', 2), ('d', 2)]);
        assert_eq!(prog.peak_resident_microbatches(0), 1);
    }

    #[test]
    fn compute_ops_respect_dependencies() {
        // In every rank's stream: a node's FwdCompute comes after the
        // RecvActivation of each of its remote inputs and before the
        // SendActivation of each of its out-edges (same microbatch).
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let prog = Program::compile(&g, &pt, 2, ScheduleKind::OneF1B);
        for part in 0..4 {
            let stream = prog.rank(part);
            let pos = |pred: &dyn Fn(&Instr) -> bool| -> usize {
                stream.iter().position(|i| pred(i)).unwrap()
            };
            for e in &pt.edges {
                for mb in 0..2 {
                    if e.dst_part == part {
                        let recv = pos(&|i: &Instr| {
                            matches!(i, Instr::RecvActivation { edge, mb: m, .. }
                                     if *edge == e.id && *m == mb)
                        });
                        let consume = pos(&|i: &Instr| {
                            matches!(i, Instr::FwdCompute { node, mb: m, .. }
                                     if *node == e.dst_node && *m == mb)
                        });
                        assert!(recv < consume, "part {part} edge {} mb {mb}", e.id);
                    }
                    if e.src_part == part {
                        let produce = pos(&|i: &Instr| {
                            matches!(i, Instr::FwdCompute { node, mb: m, .. }
                                     if *node == e.src_node && *m == mb)
                        });
                        let send = pos(&|i: &Instr| {
                            matches!(i, Instr::SendActivation { edge, mb: m, .. }
                                     if *edge == e.id && *m == mb)
                        });
                        assert!(produce < send, "part {part} edge {} mb {mb}", e.id);
                    }
                }
            }
        }
    }

    #[test]
    fn epilogue_present_once_per_rank() {
        for kind in [
            ScheduleKind::OneF1B,
            ScheduleKind::ZbH1,
            ScheduleKind::Interleaved1F1B { v: 2 },
        ] {
            let (_, prog) = program(3, 4, kind);
            for part in 0..3 {
                let n_ar = prog
                    .rank(part)
                    .iter()
                    .filter(|i| matches!(i, Instr::AllreduceGrads))
                    .count();
                let n_opt = prog
                    .rank(part)
                    .iter()
                    .filter(|i| matches!(i, Instr::OptStep))
                    .count();
                assert_eq!((n_ar, n_opt), (1, 1), "{kind:?}");
            }
        }
    }

    #[test]
    fn ir_message_order_matches_msg_schedule() {
        // The IR's per-microbatch message linearization and
        // `partition::MsgSchedule::build` implement the same §6.3 rule.
        // Pin them against divergence: the message ops of a one-microbatch
        // GPipe program must equal MsgSchedule's program op-for-op.
        use crate::partition::{MsgDir, MsgSchedule};
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let prog = Program::compile(&g, &pt, 1, ScheduleKind::GPipe);
        let ms = MsgSchedule::build(&pt);
        for part in 0..4 {
            let got: Vec<(MsgDir, usize, usize)> = prog
                .rank(part)
                .iter()
                .filter_map(|i| match *i {
                    Instr::SendActivation { edge, peer, .. } => {
                        Some((MsgDir::SendActivation, peer, edge))
                    }
                    Instr::RecvActivation { edge, peer, .. } => {
                        Some((MsgDir::RecvActivation, peer, edge))
                    }
                    Instr::SendError { edge, peer, .. } => {
                        Some((MsgDir::SendError, peer, edge))
                    }
                    Instr::RecvError { edge, peer, .. } => {
                        Some((MsgDir::RecvError, peer, edge))
                    }
                    _ => None,
                })
                .collect();
            let want: Vec<(MsgDir, usize, usize)> = ms.programs[part]
                .iter()
                .map(|m| (m.dir, m.peer, m.edge))
                .collect();
            assert_eq!(got, want, "partition {part} diverged from MsgSchedule");
        }
    }

    #[test]
    fn schedule_kind_parses() {
        assert_eq!(ScheduleKind::parse("gpipe").unwrap(), ScheduleKind::GPipe);
        assert_eq!(ScheduleKind::parse("1f1b").unwrap(), ScheduleKind::OneF1B);
        assert_eq!(
            ScheduleKind::parse("interleaved_1f1b").unwrap(),
            ScheduleKind::Interleaved1F1B { v: 2 }
        );
        assert_eq!(
            ScheduleKind::parse("interleaved_1f1b:v=4").unwrap(),
            ScheduleKind::Interleaved1F1B { v: 4 }
        );
        // v=1 is plain 1F1B.
        assert_eq!(
            ScheduleKind::parse("interleaved_1f1b:v=1").unwrap(),
            ScheduleKind::OneF1B
        );
        assert_eq!(ScheduleKind::parse("zb_h1").unwrap(), ScheduleKind::ZbH1);
        assert_eq!(ScheduleKind::parse("zbh1").unwrap(), ScheduleKind::ZbH1);
    }

    #[test]
    fn unknown_schedule_is_a_hard_error_listing_valid_kinds() {
        for bad in ["zigzag", "", "interleaved_1f1b:v=0", "interleaved_1f1b:v=x", "1f1b "] {
            let err = ScheduleKind::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains(VALID_SCHEDULES),
                "error for '{bad}' must list valid schedules: {err}"
            );
        }
    }

    #[test]
    fn labels_and_names() {
        assert_eq!(ScheduleKind::Interleaved1F1B { v: 3 }.label(), "interleaved_1f1b:v=3");
        assert_eq!(ScheduleKind::Interleaved1F1B { v: 3 }.name(), "interleaved_1f1b");
        assert_eq!(ScheduleKind::ZbH1.label(), "zb_h1");
        assert_eq!(ScheduleKind::GPipe.virtual_stages(), 1);
        assert_eq!(ScheduleKind::Interleaved1F1B { v: 3 }.virtual_stages(), 3);
    }

    #[test]
    fn peak_activation_bytes_matches_residency_for_flat_schedules() {
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mb = 4;
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B, ScheduleKind::ZbH1] {
            let prog = Program::compile(&g, &pt, 8, kind);
            for rank in 0..4 {
                let per_mb: u64 = pt.parts[rank]
                    .iter()
                    .map(|&n| g.nodes[n].out_shape.iter().product::<usize>() as u64 * 4 * mb)
                    .sum();
                assert_eq!(
                    prog.peak_activation_bytes(&g, &pt, rank, mb as usize),
                    per_mb * prog.peak_resident_microbatches(rank) as u64,
                    "{kind:?} rank {rank}"
                );
            }
        }
    }
}
