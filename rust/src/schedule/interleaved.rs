//! Megatron-style interleaved 1F1B generator.
//!
//! The partitioner cuts the model into `S = R * v` contiguous chunks
//! (stage-level [`Partitioning`]) and stage `s` runs on rank `s % R`, so
//! each rank owns `v` chunks spread across the pipeline. A microbatch now
//! visits every rank `v` times per direction; the fill/drain bubble per
//! visit is the per-*chunk* compute time, ~1/v of a flat stage's, which is
//! the whole point (Narayanan et al., PAPERS.md).
//!
//! **Ordering.** Forward work on rank `r` proceeds in groups of `R`
//! microbatches: within group `j` (microbatches `j*R .. min((j+1)*R, m)`,
//! the last group may be ragged), chunks ascend `0..v` and microbatches
//! ascend within each chunk. Backward mirrors the group with chunks
//! *descending*, so chunk 0's backward is a microbatch's last touch on the
//! rank and carries its `DropStash`. The warmup depth
//! `w = min((R-1-r)*2 + (v-1)*R, m*v)` is Megatron's: deep enough that
//! chunk `v-1`'s first forward input has arrived before the first backward
//! is due, shrinking by 2 per downstream rank. After warmup the rank
//! alternates one forward, one backward (over *virtual* microbatches =
//! (chunk, mb) pairs), then drains the remaining backwards.
//!
//! Messages between two stages of the same rank are elided on both ends
//! (see `fwd_phase`/`bwd_phase`): the group ordering guarantees the
//! producer chunk's compute precedes the consumer chunk's in the rank's
//! own stream, so the activation (forward) or accumulated error (backward)
//! is already rank-local. Cross-rank messages keep the §6.3 per-phase
//! linearization. The result passes the buffered-send checker and the
//! pairing verifier for random `(R, v, m)` — fuzzed in
//! `rust/tests/proptests.rs` and `rust/tests/schedule_conformance.rs`.

use super::{bwd_phase, fwd_phase, Instr, Program, ScheduleKind, SendMode};
use crate::graph::ModelGraph;
use crate::partition::Partitioning;

pub(super) fn compile(g: &ModelGraph, pt: &Partitioning, m: usize, v: usize) -> Program {
    let stages = pt.num_partitions;
    assert!(
        v >= 2 && stages % v == 0 && stages >= v,
        "interleaved_1f1b:v={v} needs a stage-level partitioning with a multiple of v \
         partitions, got {stages} (build it via ScheduleKind::partitioning)"
    );
    let p = stages / v;
    let mut ranks = Vec::with_capacity(p);
    for r in 0..p {
        // Virtual-microbatch sequences in groups of `p` microbatches.
        let mut fseq: Vec<(usize, usize)> = Vec::with_capacity(m * v);
        let mut bseq: Vec<(usize, usize)> = Vec::with_capacity(m * v);
        let mut lo = 0;
        while lo < m {
            let hi = (lo + p).min(m);
            for c in 0..v {
                for mb in lo..hi {
                    fseq.push((c, mb));
                }
            }
            for c in (0..v).rev() {
                for mb in lo..hi {
                    bseq.push((c, mb));
                }
            }
            lo = hi;
        }
        let w = ((p - 1 - r) * 2 + (v - 1) * p).min(m * v);
        let mut prog = vec![];
        let mut emit_f = |(c, mb): (usize, usize), prog: &mut Vec<Instr>| {
            fwd_phase(pt, c * p + r, p, mb, prog);
        };
        let mut emit_b = |(c, mb): (usize, usize), prog: &mut Vec<Instr>| {
            bwd_phase(g, pt, c * p + r, p, mb, false, c == 0, prog);
        };
        for &f in &fseq[..w] {
            emit_f(f, &mut prog);
        }
        for i in w..fseq.len() {
            emit_f(fseq[i], &mut prog);
            emit_b(bseq[i - w], &mut prog);
        }
        for &b in &bseq[fseq.len() - w..] {
            emit_b(b, &mut prog);
        }
        prog.push(Instr::AllreduceGrads);
        prog.push(Instr::OptStep);
        ranks.push(prog);
    }
    Program {
        kind: ScheduleKind::Interleaved1F1B { v },
        send_mode: SendMode::Blocking,
        num_microbatches: m,
        num_partitions: p,
        num_stages: stages,
        ranks,
    }
}
