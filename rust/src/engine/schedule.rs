//! Learning-rate schedules. The paper's accuracy runs use the Keras
//! cifar10_resnet schedule (piecewise decay at epochs 80/120/160/180);
//! this module provides that shape plus the constant and warmup variants
//! used by the examples.

/// A learning-rate schedule: step -> lr.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Constant(f32),
    /// Piecewise constant: starts at `base`, multiplied by `factor` at
    /// each boundary step.
    StepDecay { base: f32, boundaries: Vec<u64>, factor: f32 },
    /// Piecewise constant with a *per-boundary* factor: at each
    /// `(boundary, factor)` the current lr is multiplied by that factor.
    /// This is the shape of the Keras cifar10_resnet schedule, whose final
    /// drop (x0.5 at epoch 180) differs from the earlier x0.1 drops.
    MultiStepDecay { base: f32, drops: Vec<(u64, f32)> },
    /// Linear warmup over `warmup` steps to `base`, then constant — the
    /// standard large-batch data-parallel recipe (Goyal et al., cited by
    /// the paper as DP practice).
    Warmup { base: f32, warmup: u64 },
}

impl LrSchedule {
    /// The Keras cifar10_resnet schedule the paper trains with:
    /// 1e-3, x0.1 at epoch 80, x0.1 at 120, x0.1 at 160, and the final
    /// x0.5 at 180 (the reference's `lr *= 0.5e-3` tail).
    pub fn keras_cifar(base: f32, steps_per_epoch: u64) -> LrSchedule {
        LrSchedule::MultiStepDecay {
            base,
            drops: vec![
                (80 * steps_per_epoch, 0.1),
                (120 * steps_per_epoch, 0.1),
                (160 * steps_per_epoch, 0.1),
                (180 * steps_per_epoch, 0.5),
            ],
        }
    }

    pub fn at(&self, step: u64) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::StepDecay { base, boundaries, factor } => {
                let drops = boundaries.iter().filter(|&&b| step >= b).count() as i32;
                base * factor.powi(drops)
            }
            LrSchedule::MultiStepDecay { base, drops } => {
                let mut lr = *base;
                for &(b, f) in drops {
                    if step >= b {
                        lr *= f;
                    }
                }
                lr
            }
            LrSchedule::Warmup { base, warmup } => {
                if step >= *warmup || *warmup == 0 {
                    *base
                } else {
                    base * (step + 1) as f32 / *warmup as f32
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant(0.1);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(1_000_000), 0.1);
    }

    #[test]
    fn step_decay_drops_at_boundaries() {
        let s = LrSchedule::StepDecay { base: 1.0, boundaries: vec![10, 20], factor: 0.1 };
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(10) - 0.1).abs() < 1e-7);
        assert!((s.at(19) - 0.1).abs() < 1e-7);
        assert!((s.at(20) - 0.01).abs() < 1e-7);
    }

    #[test]
    fn keras_schedule_shape() {
        let s = LrSchedule::keras_cifar(1e-3, 100);
        assert_eq!(s.at(0), 1e-3);
        assert!((s.at(80 * 100) - 1e-4).abs() < 1e-9);
        assert!((s.at(120 * 100) - 1e-5).abs() < 1e-10);
        assert!((s.at(160 * 100) - 1e-6).abs() < 1e-11);
        // The fourth drop: x0.5 at epoch 180 (0.5e-3 of base in total).
        assert!((s.at(180 * 100) - 5e-7).abs() < 1e-12);
        assert!((s.at(179 * 100 + 99) - 1e-6).abs() < 1e-11);
    }

    #[test]
    fn multi_step_factors_compose_in_order() {
        let s = LrSchedule::MultiStepDecay {
            base: 1.0,
            drops: vec![(10, 0.1), (20, 0.5)],
        };
        assert_eq!(s.at(9), 1.0);
        assert!((s.at(15) - 0.1).abs() < 1e-7);
        assert!((s.at(25) - 0.05).abs() < 1e-7);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::Warmup { base: 0.4, warmup: 4 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(1) - 0.2).abs() < 1e-6);
        assert_eq!(s.at(4), 0.4);
        assert_eq!(s.at(100), 0.4);
    }
}
