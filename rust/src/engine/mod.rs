//! The **Trainer** (paper §6.2 / Listing 3): per-rank distributed training
//! loop implementing forward and backward passes over one model-partition,
//! with microbatch pipelining, grad-layer partial-error exchange, and
//! data-parallel gradient averaging.
//!
//! Execution model per training step (GPipe-style fill/drain, the paper's
//! "pipelining via batch splitting"):
//!
//! 1. **Forward**: for each microbatch, run this partition's nodes in
//!    topological order. Cross-partition inputs are received (tag =
//!    edge x microbatch); produced outputs that feed remote partitions are
//!    sent eagerly. The first partition materializes `x` from the dataset,
//!    the last one runs the loss head (labels materialized locally — the
//!    dataset is index-deterministic).
//! 2. **Backward**: reverse order. A node's output-gradient is the sum of
//!    its local consumers' input-gradients and the partial errors received
//!    from remote consumers (the paper's *grad layer* per recv, Eq. 5-6).
//!    Parameter gradients accumulate across microbatches; input gradients
//!    propagate locally or are sent as partial errors.
//! 3. **Update**: average gradients over microbatches, allreduce across
//!    replicas (per-partition communicator, fused), SGD+momentum step.
//!
//! Because every rank runs the same node-level math as sequential execution
//! (partitioning only moves ops, never changes them), model-parallel
//! training is *bitwise* equivalent to sequential — asserted by
//! `rust/tests/equivalence.rs`, the machine check of the paper's §6.1
//! "sequential semantics" guarantee.

pub mod checkpoint;
mod optimizer;
mod schedule;

pub use optimizer::SgdMomentum;
pub use schedule::LrSchedule;

use crate::comm::CommEngine;
use crate::data::SyntheticDataset;
use crate::graph::{LayerKind, ModelGraph, NodeId};
use crate::partition::Partitioning;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{Shape, Tensor};
use std::collections::HashMap;

/// Engine configuration (per run).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Microbatch size — must match the `n` the artifacts were compiled for.
    pub microbatch: usize,
    /// Microbatches per step (pipeline depth). Per-replica batch =
    /// microbatch * num_microbatches.
    pub num_microbatches: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// Optional schedule; overrides `lr` per step when set (the paper's
    /// accuracy runs use `LrSchedule::keras_cifar`).
    pub lr_schedule: Option<LrSchedule>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            microbatch: 8,
            num_microbatches: 1,
            lr: 0.01,
            momentum: 0.9,
            seed: 42,
            lr_schedule: None,
        }
    }
}

/// Metrics of one training (or eval) step, reported by the last partition.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub accuracy: f32,
    /// Samples processed this step on this replica.
    pub samples: usize,
    pub step_secs: f64,
}

/// Per-rank trainer state.
pub struct Trainer<'a> {
    pub g: &'a ModelGraph,
    pub pt: &'a Partitioning,
    pub cfg: EngineConfig,
    pub ce: &'a CommEngine,
    rt: &'a Runtime,
    data: SyntheticDataset,
    /// node -> parameter tensors (only for nodes on this partition).
    pub params: HashMap<NodeId, Vec<Tensor>>,
    opt: SgdMomentum,
    /// Nodes of this partition in topological order.
    my_nodes: Vec<NodeId>,
    /// Deterministic order of (node, slot) for fused allreduce packing.
    param_order: Vec<(NodeId, usize)>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        g: &'a ModelGraph,
        pt: &'a Partitioning,
        cfg: EngineConfig,
        ce: &'a CommEngine,
        rt: &'a Runtime,
        data: SyntheticDataset,
    ) -> anyhow::Result<Trainer<'a>> {
        let my_nodes = pt.parts[ce.partition].clone();
        // Global parameter ordinal per node: number of parameter slots in
        // all earlier nodes. Seeding init by ordinal (not node id) makes
        // initialization invariant under graph rewrites that preserve the
        // parameter sequence — e.g. conv+bn+relu fusion — so a fused model
        // trains from the same weights as its unfused original.
        let mut ordinal_base = vec![0usize; g.num_nodes()];
        let mut acc = 0usize;
        for (i, node) in g.nodes.iter().enumerate() {
            ordinal_base[i] = acc;
            acc += node.params.len();
        }
        let mut params = HashMap::new();
        let mut param_order = vec![];
        for &n in &my_nodes {
            let node = &g.nodes[n];
            if node.params.is_empty() {
                continue;
            }
            let mut slots = vec![];
            for (si, spec) in node.params.iter().enumerate() {
                // Deterministic init from (seed, param ordinal): every
                // replica computes identical weights, and so does the
                // sequential baseline — the foundation of the equivalence
                // tests.
                let t = if spec.fan_in > 0 {
                    let mut rng = Rng::new(
                        cfg.seed
                            .wrapping_mul(0x1000193)
                            .wrapping_add(((ordinal_base[n] + si) as u64) << 8),
                    );
                    Tensor::he_normal(&spec.dims, spec.fan_in, &mut rng)
                } else if spec.role == "gamma" {
                    Tensor::ones(&spec.dims)
                } else {
                    Tensor::zeros(&spec.dims)
                };
                slots.push(t);
                param_order.push((n, si));
            }
            params.insert(n, slots);
        }
        // Paper-faithful init sync: broadcast from replica 0 (a no-op on the
        // values here since init is deterministic, but exercises the CE path
        // the paper requires).
        let mut bc: Vec<(NodeId, usize)> = param_order.clone();
        bc.sort();
        for (i, (n, si)) in bc.iter().enumerate() {
            let t = &mut params.get_mut(n).unwrap()[*si];
            ce.bcast_param(t, i);
        }
        let opt = SgdMomentum::new(cfg.lr, cfg.momentum, &param_order, &params);
        Ok(Trainer { g, pt, cfg, ce, rt, data, params, opt, my_nodes, param_order })
    }

    /// Batch size processed per step per replica.
    pub fn replica_batch(&self) -> usize {
        self.cfg.microbatch * self.cfg.num_microbatches
    }

    /// Global sample index base for (step, replica, microbatch).
    fn sample_base(&self, step: u64, mb: usize) -> u64 {
        let ebs = (self.replica_batch() * self.ce.replica.size()) as u64;
        step * ebs
            + (self.ce.replica_id * self.replica_batch()) as u64
            + (mb * self.cfg.microbatch) as u64
    }

    fn is_first_partition(&self) -> bool {
        self.ce.partition == 0
    }

    fn is_last_partition(&self) -> bool {
        self.ce.partition == self.pt.num_partitions - 1
    }

    /// Forward one microbatch; fills `acts` (node -> output) and returns
    /// (loss, glogits, labels) on the last partition.
    fn forward_microbatch(
        &self,
        step: u64,
        mb: usize,
        test: bool,
        acts: &mut HashMap<NodeId, Tensor>,
    ) -> anyhow::Result<Option<(f32, Tensor, Vec<usize>)>> {
        let n_mb = self.cfg.microbatch;
        let base = self.sample_base(step, mb);
        let mut head = None;
        for &nid in &self.my_nodes {
            let node = &self.g.nodes[nid];
            // Phase 1 — satisfy remote inputs: receive and stash under the
            // *producer* id (the backward pass recomputes from these — the
            // state the paper's grad layers close over).
            for (slot, &src) in node.inputs.iter().enumerate() {
                if self.pt.assign[src] != self.ce.partition {
                    let e = self
                        .pt
                        .edges
                        .iter()
                        .find(|e| e.src_node == src && e.dst_node == nid)
                        .unwrap_or_else(|| panic!("missing edge {src}->{nid} slot {slot}"));
                    // Always consume the message (the producer sends one
                    // per edge); duplicates of an already-stashed producer
                    // are identical payloads.
                    let t = self.ce.recv_activation(e.src_part, e.id, mb);
                    acts.insert(src, t);
                }
            }
            // Phase 2 — borrow inputs from the stash (no clones on the hot
            // path; every producer, local or received, is in `acts` now).
            let inputs: Vec<&Tensor> = node.inputs.iter().map(|src| &acts[src]).collect();
            let out = match &node.kind {
                LayerKind::Input => {
                    debug_assert!(self.is_first_partition() || !node.inputs.is_empty());
                    let (x, _, _) = if test {
                        self.data.test_batch(base, n_mb)
                    } else {
                        self.data.batch(base, n_mb)
                    };
                    x
                }
                LayerKind::Add => {
                    let mut s = inputs[0].clone();
                    s.add_assign(&inputs[1]);
                    s
                }
                LayerKind::Flatten => {
                    let t = inputs[0];
                    let flat: usize = t.shape.dims()[1..].iter().product();
                    Tensor::new(Shape::new(&[t.batch(), flat]), t.data.clone())
                }
                LayerKind::SoftmaxXent => {
                    let (_, y, labels) = if test {
                        self.data.test_batch(base, n_mb)
                    } else {
                        self.data.batch(base, n_mb)
                    };
                    let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                        .expect("loss artifact");
                    let outs = self.rt.exec(&art.fwd, &[inputs[0], &y])?;
                    let loss = outs[0].data[0];
                    head = Some((loss, outs[1].clone(), labels));
                    // The loss node's "activation" is its glogits (only used
                    // locally in backward).
                    outs[1].clone()
                }
                _ => {
                    let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                        .expect("artifact for compute node");
                    // Python signature: fwd(x, params...).
                    let mut args: Vec<&Tensor> = vec![inputs[0]];
                    let slots = self.params.get(&nid);
                    if let Some(slots) = slots {
                        args.extend(slots.iter());
                    }
                    let outs = self.rt.exec(&art.fwd, &args)?;
                    outs.into_iter().next().unwrap()
                }
            };
            // Eager sends on all out-edges (consumer-node order — matches
            // the deadlock-free schedule; hfmpi buffers, so never blocks).
            let mut out_edges = self.pt.out_edges_of_node(nid);
            out_edges.sort_by_key(|e| (e.dst_node, e.src_node));
            for e in out_edges {
                self.ce.send_activation(&out, e.dst_part, e.id, mb);
            }
            acts.insert(nid, out);
        }
        Ok(head)
    }

    /// Backward one microbatch given the forward stash; accumulates
    /// parameter gradients into `grads`.
    fn backward_microbatch(
        &self,
        mb: usize,
        acts: &HashMap<NodeId, Tensor>,
        glogits: Option<&Tensor>,
        grads: &mut HashMap<NodeId, Vec<Tensor>>,
    ) -> anyhow::Result<()> {
        let n_mb = self.cfg.microbatch;
        // Output-gradient accumulator per node.
        let mut gout: HashMap<NodeId, Tensor> = HashMap::new();
        for &nid in self.my_nodes.iter().rev() {
            let node = &self.g.nodes[nid];
            if matches!(node.kind, LayerKind::Input) {
                continue; // data has no gradient
            }
            // 1) Assemble dL/d(out of nid).
            let mut gy = match &node.kind {
                LayerKind::SoftmaxXent => {
                    // Loss root: gradient w.r.t. logits was computed in fwd.
                    // Handled below as the gradient *to its input*; gy unused.
                    None
                }
                _ => gout.remove(&nid),
            };
            // Remote consumers' partial errors (grad-layer recv), in the
            // mirror of the forward send order.
            let mut out_edges = self.pt.out_edges_of_node(nid);
            out_edges.sort_by_key(|e| (std::cmp::Reverse(e.dst_node), e.src_node));
            for e in out_edges {
                let err = self.ce.recv_error(e.dst_part, e.id, mb);
                match &mut gy {
                    Some(t) => t.add_assign(&err),
                    None => gy = Some(err),
                }
            }
            if !matches!(node.kind, LayerKind::SoftmaxXent) && gy.is_none() {
                // Dead-end node (shouldn't happen in validated graphs).
                continue;
            }
            // 2) Compute input gradients (+ parameter gradients).
            let gins: Vec<(NodeId, Tensor)> = match &node.kind {
                LayerKind::SoftmaxXent => {
                    let g = glogits.expect("loss backward needs fwd glogits").clone();
                    vec![(node.inputs[0], g)]
                }
                LayerKind::Add => {
                    let gy = gy.unwrap();
                    vec![(node.inputs[0], gy.clone()), (node.inputs[1], gy)]
                }
                LayerKind::Flatten => {
                    let gy = gy.unwrap();
                    let src = node.inputs[0];
                    let mut dims = vec![gy.batch()];
                    dims.extend_from_slice(&self.g.nodes[src].out_shape);
                    vec![(src, Tensor::new(Shape(dims), gy.data))]
                }
                kind => {
                    let gy = gy.unwrap();
                    let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                        .expect("artifact for compute node");
                    let bwd = art.bwd.as_ref().expect("non-loss node has bwd");
                    // Python signatures (model.instance):
                    //   conv/bn/dense: bwd(x, <param subset>, gy)
                    //   relu/pool:     bwd(x, gy)
                    //   gap:           bwd(gy)        (x only matters for shape)
                    let slots = self.params.get(&nid);
                    let mut args: Vec<&Tensor> = vec![];
                    if !matches!(kind, LayerKind::GlobalAvgPool) {
                        args.push(self.node_input_act(nid, acts));
                    }
                    match kind {
                        LayerKind::Conv3x3 { .. } | LayerKind::Conv1x1 { .. } => {
                            args.push(&slots.unwrap()[0]); // w
                        }
                        LayerKind::ConvBnRelu { .. } => {
                            let s = slots.unwrap();
                            args.extend([&s[0], &s[1], &s[2]]); // w, gamma, beta
                        }
                        LayerKind::BatchNorm => {
                            args.push(&slots.unwrap()[0]); // gamma
                        }
                        LayerKind::Dense { .. } => {
                            args.push(&slots.unwrap()[0]); // w
                        }
                        LayerKind::DenseRelu { .. } => {
                            let s = slots.unwrap();
                            args.extend([&s[0], &s[1]]); // w, b
                        }
                        _ => {}
                    }
                    args.push(&gy);
                    let mut outs = self.rt.exec(bwd, &args)?;
                    // outs[0] = gx; outs[1..] = parameter gradients in the
                    // same slot order as node.params.
                    let gx = outs.remove(0);
                    if !outs.is_empty() {
                        let slot_grads = grads.entry(nid).or_insert_with(|| {
                            outs.iter()
                                .map(|t| Tensor::zeros(t.shape.dims()))
                                .collect()
                        });
                        for (acc, g) in slot_grads.iter_mut().zip(outs.iter()) {
                            acc.add_assign(g);
                        }
                    }
                    vec![(node.inputs[0], gx)]
                }
            };
            // 3) Route input gradients: local accumulate or remote send.
            for (src, gin) in gins {
                if self.pt.assign[src] == self.ce.partition {
                    match gout.get_mut(&src) {
                        Some(t) => t.add_assign(&gin),
                        None => {
                            gout.insert(src, gin);
                        }
                    }
                } else {
                    let e = self
                        .pt
                        .edges
                        .iter()
                        .find(|e| e.src_node == src && e.dst_node == nid)
                        .expect("cross edge for backward send");
                    self.ce.send_error(&gin, e.src_part, e.id, mb);
                }
            }
        }
        Ok(())
    }

    /// The stashed input activation of node `nid` (its first input's
    /// output). For cross-partition inputs the forward pass stashed the
    /// received tensor under the producer id.
    fn node_input_act<'b>(
        &self,
        nid: NodeId,
        acts: &'b HashMap<NodeId, Tensor>,
    ) -> &'b Tensor {
        let src = self.g.nodes[nid].inputs[0];
        acts.get(&src).expect("input activation stashed")
    }

    /// One full training step (all microbatches + update). Returns the
    /// replica-local metrics (meaningful on the last partition).
    pub fn train_step(&mut self, step: u64) -> anyhow::Result<StepMetrics> {
        let t0 = std::time::Instant::now();
        if let Some(s) = &self.cfg.lr_schedule {
            self.opt.lr = s.at(step);
        }
        let m = self.cfg.num_microbatches;
        let mut stashes: Vec<HashMap<NodeId, Tensor>> = Vec::with_capacity(m);
        let mut heads: Vec<Option<(f32, Tensor, Vec<usize>)>> = Vec::with_capacity(m);

        // ---- forward fill ----
        for mb in 0..m {
            let mut acts = HashMap::new();
            heads.push(self.forward_microbatch(step, mb, false, &mut acts)?);
            stashes.push(acts);
        }

        // ---- backward drain (reverse microbatch order) ----
        let mut grads: HashMap<NodeId, Vec<Tensor>> = HashMap::new();
        for mb in (0..m).rev() {
            let glogits = heads[mb].as_ref().map(|(_, g, _)| g);
            // Forward-received activations for cross inputs are needed in
            // backward too: restash them (they live in stashes[mb] already
            // because forward inserted received tensors under producer ids
            // only when consumed... see forward_microbatch note).
            self.backward_microbatch(mb, &stashes[mb], glogits, &mut grads)?;
        }

        // ---- average over microbatches ----
        let inv_m = 1.0 / m as f32;
        for slots in grads.values_mut() {
            for t in slots.iter_mut() {
                t.scale(inv_m);
            }
        }

        // ---- data-parallel allreduce (per-partition communicator) ----
        let mut flat: Vec<&mut Tensor> = vec![];
        let order = self.param_order.clone();
        {
            // Deterministic packing order across replicas.
            let mut by_node: HashMap<NodeId, &mut Vec<Tensor>> =
                grads.iter_mut().map(|(k, v)| (*k, v)).collect();
            let mut staged: Vec<(usize, &mut Tensor)> = vec![];
            for (i, (n, si)) in order.iter().enumerate() {
                if let Some(slots) = by_node.remove(n) {
                    for (j, t) in slots.iter_mut().enumerate() {
                        staged.push((i * 16 + j, t));
                    }
                    let _ = si;
                }
            }
            staged.sort_by_key(|(k, _)| *k);
            flat = staged.into_iter().map(|(_, t)| t).collect();
        }
        self.ce.allreduce_grads(&mut flat)?;
        drop(flat);

        // ---- optimizer ----
        self.opt.step(&order, &mut self.params, &grads);

        // ---- metrics (last partition) ----
        let mut metrics = StepMetrics {
            samples: self.replica_batch() * self.ce.replica.size(),
            ..Default::default()
        };
        if self.is_last_partition() {
            let (mut loss_sum, mut correct, mut total) = (0.0f32, 0usize, 0usize);
            for h in heads.iter().flatten() {
                let (loss, glogits, labels) = h;
                loss_sum += loss;
                let (c, t) = accuracy_from_glogits(glogits, labels, self.cfg.microbatch);
                correct += c;
                total += t;
            }
            let mut mtr = Tensor::new(
                Shape::new(&[2]),
                vec![loss_sum / m as f32, correct as f32 / total.max(1) as f32],
            );
            self.ce.allreduce_metrics(&mut mtr)?;
            metrics.loss = mtr.data[0];
            metrics.accuracy = mtr.data[1];
        }
        metrics.step_secs = t0.elapsed().as_secs_f64();
        Ok(metrics)
    }

    /// Forward-only evaluation over `batches` test microbatches.
    /// Returns (loss, accuracy) on the last partition.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<StepMetrics> {
        let mut loss_sum = 0.0f32;
        let (mut correct, mut total) = (0usize, 0usize);
        for b in 0..batches {
            let mut acts = HashMap::new();
            // Use the test index space; spread replicas across it.
            let head = self.forward_microbatch(b as u64, 0, true, &mut acts)?;
            if let Some((loss, glogits, labels)) = head {
                loss_sum += loss;
                let (c, t) = accuracy_from_glogits(&glogits, &labels, self.cfg.microbatch);
                correct += c;
                total += t;
            }
        }
        let mut metrics = StepMetrics::default();
        if self.is_last_partition() {
            let mut mtr = Tensor::new(
                Shape::new(&[2]),
                vec![
                    loss_sum / batches.max(1) as f32,
                    correct as f32 / total.max(1) as f32,
                ],
            );
            self.ce.allreduce_metrics(&mut mtr)?;
            metrics.loss = mtr.data[0];
            metrics.accuracy = mtr.data[1];
            metrics.samples = total;
        }
        Ok(metrics)
    }

    /// Snapshot of this rank's parameters keyed by (node, slot) — used by
    /// the equivalence tests and checkpoint-style export.
    pub fn export_params(&self) -> Vec<((NodeId, usize), Tensor)> {
        let mut out = vec![];
        for &(n, si) in &self.param_order {
            out.push(((n, si), self.params[&n][si].clone()));
        }
        out
    }

    /// Names of the artifacts this partition executes (for warmup).
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v = vec![];
        for &n in &self.my_nodes {
            if let Some(a) =
                crate::graph::artifact::node_artifact(self.g, n, self.cfg.microbatch)
            {
                v.push(a.fwd.clone());
                if let Some(b) = a.bwd {
                    v.push(b);
                }
            }
        }
        v
    }
}

/// Recover predictions from the loss node's glogits:
/// glogits = (softmax(logits) - y) / n  =>  probs = glogits * n + y.
/// Since y is one-hot and softmax is monotone, argmax(probs) works directly.
fn accuracy_from_glogits(glogits: &Tensor, labels: &[usize], n_mb: usize) -> (usize, usize) {
    let classes = glogits.shape.dims()[1];
    let mut correct = 0;
    for (i, &l) in labels.iter().enumerate() {
        let row = &glogits.data[i * classes..(i + 1) * classes];
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (c, &g) in row.iter().enumerate() {
            let p = g * n_mb as f32 + if c == l { 1.0 } else { 0.0 };
            if p > best_v {
                best_v = p;
                best = c;
            }
        }
        if best == l {
            correct += 1;
        }
    }
    (correct, labels.len())
}
