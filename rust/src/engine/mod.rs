//! The **Trainer** (paper §6.2 / Listing 3): per-rank distributed training
//! as an **interpreter of the pipeline-schedule IR** (`crate::schedule`).
//!
//! `Trainer::new` compiles the `(ModelGraph, Partitioning,
//! num_microbatches, ScheduleKind)` quadruple into a per-rank
//! [`Program`](crate::schedule::Program); `train_step` then executes this
//! rank's instruction stream:
//!
//! - `FwdCompute {node, mb}` — run the node's forward on microbatch `mb`.
//!   Inputs come from the stash (local producers computed earlier, remote
//!   ones received). The first partition materializes `x` from the
//!   dataset; the last one runs the loss head (labels materialized
//!   locally — the dataset is index-deterministic, so no label shipping
//!   is needed).
//! - `Send/RecvActivation` — boundary/skip-edge traffic (tag =
//!   edge x microbatch), ordered by the IR's deadlock-safe linearization
//!   (paper §6.3).
//! - `BwdCompute {node, mb}` — a node's output-gradient is the sum of its
//!   local consumers' input-gradients and the partial errors received from
//!   remote consumers (the paper's *grad layer*, Eq. 5-6), all accumulated
//!   into the per-microbatch `gout` map *in instruction order*. Parameter
//!   gradients accumulate across microbatches in the order the schedule
//!   runs backwards — which is why GPipe reproduces the original fill/
//!   drain loop bitwise.
//! - `BwdInput {node, mb}` / `BwdWeight {node, mb}` — the ZB-H1 split
//!   backward: `BwdInput` runs the same kernel as `BwdCompute` but *parks*
//!   the parameter gradients under `(node, mb)`; the matching `BwdWeight`
//!   (scheduled later, into the drain bubble) retires them into the
//!   cross-microbatch accumulators. The kernel runs once, so the split is
//!   bitwise-neutral; only the accumulation instant moves.
//! - `Send/RecvError` — partial-error traffic, mirrored ordering.
//! - `DropStash {mb}` — the microbatch's activations and gradient
//!   accumulators are dead; under 1F1B this is what bounds live stashes
//!   to the pipeline depth instead of `num_microbatches`.
//! - `AllreduceGrads` / `OptStep` — microbatch-average, data-parallel
//!   allreduce (per-partition communicator, fused), SGD+momentum step.
//!
//! Because every rank runs the same node-level math as sequential
//! execution (the schedule only moves ops, never changes them), training
//! under either generator is *bitwise* equivalent to sequential execution
//! under the same schedule kind — asserted by `rust/tests/equivalence.rs`,
//! the machine check of the paper's §6.1 "sequential semantics" guarantee.

pub mod checkpoint;
mod optimizer;
mod schedule;

pub use optimizer::SgdMomentum;
pub use schedule::LrSchedule;

use crate::comm::{CommEngine, SendHandle};
use crate::data::SyntheticDataset;
use crate::graph::{LayerKind, ModelGraph, NodeId};
use crate::partition::Partitioning;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::schedule::{Instr, Program, ScheduleKind, SendMode};
use crate::tensor::{Shape, Tensor};
use std::collections::HashMap;

/// Engine configuration (per run).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Microbatch size — must match the `n` the artifacts are built for.
    pub microbatch: usize,
    /// Microbatches per step (pipeline depth). Per-replica batch =
    /// microbatch * num_microbatches.
    pub num_microbatches: usize,
    /// Pipeline schedule interpreted by the Trainer (and, identically, by
    /// the simulator and the memory model).
    pub schedule: ScheduleKind,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// Optional schedule; overrides `lr` per step when set (the paper's
    /// accuracy runs use `LrSchedule::keras_cifar`).
    pub lr_schedule: Option<LrSchedule>,
    /// Compile the training program with eager (MPI_Isend-style)
    /// `PostSend*`/`WaitSend` pairs instead of blocking sends. Payloads,
    /// arithmetic and message order are identical — only the completion
    /// point moves — so training is bitwise-equal either way; eager
    /// programs are additionally deadlock-free on rendezvous-only
    /// transports — including the live fabric's
    /// [`crate::hfmpi::Transport::Rendezvous`] mode, where blocking
    /// 1F1B-family programs deadlock on their facing send pairs. Default:
    /// on (`HF_EAGER_SENDS=0` disables, which is how CI exercises the
    /// blocking/buffered row of the transport matrix).
    pub eager_sends: bool,
    /// Record an hftrace timeline of every interpreted instruction (plus
    /// comm/kernel sub-spans) per rank. Observation-only: payloads,
    /// ordering and arithmetic are bitwise identical either way, and the
    /// disabled path takes no timestamps at all. Default: off
    /// (`HF_TRACE=1` enables).
    pub trace: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            microbatch: 8,
            num_microbatches: 1,
            schedule: ScheduleKind::GPipe,
            lr: 0.01,
            momentum: 0.9,
            seed: 42,
            lr_schedule: None,
            eager_sends: eager_sends_from_env(),
            trace: trace_from_env(),
        }
    }
}

/// `HF_EAGER_SENDS=0|false|off` opts the engine back into blocking sends.
/// Unrecognized values hard-error (mirroring `ScheduleKind::parse`) instead
/// of silently training on the default transport.
fn eager_sends_from_env() -> bool {
    crate::util::env_flag("HF_EAGER_SENDS", true).unwrap_or_else(|e| panic!("{e:#}"))
}

/// `HF_TRACE=1|true|on` turns tracing on by default; unrecognized values
/// hard-error just like `HF_EAGER_SENDS`.
fn trace_from_env() -> bool {
    crate::util::env_flag("HF_TRACE", false).unwrap_or_else(|e| panic!("{e:#}"))
}

/// Metrics of one training (or eval) step, reported by the last partition.
#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub accuracy: f32,
    /// Samples processed this step on this replica.
    pub samples: usize,
    pub step_secs: f64,
}

/// The forward head captured at the loss node: (loss, glogits, labels).
type Head = (f32, Tensor, Vec<usize>);

/// Per-rank trainer state.
pub struct Trainer<'a> {
    pub g: &'a ModelGraph,
    pub pt: &'a Partitioning,
    pub cfg: EngineConfig,
    pub ce: &'a CommEngine,
    rt: &'a Runtime,
    data: SyntheticDataset,
    /// node -> parameter tensors (only for nodes on this partition).
    pub params: HashMap<NodeId, Vec<Tensor>>,
    opt: SgdMomentum,
    /// The compiled per-rank schedule program this trainer interprets.
    program: Program,
    /// Forward-only program for evaluation.
    eval_program: Program,
    /// Deterministic order of (node, slot) for fused allreduce packing.
    param_order: Vec<(NodeId, usize)>,
    /// Nodes this rank executes — the union of its stages' partitions
    /// (one stage for flat schedules, `v` chunks under interleaved).
    my_nodes: Vec<NodeId>,
    /// hftrace recording handle (off unless `fit` attaches one).
    tracer: crate::trace::Tracer,
    /// Resident parameter bytes on this rank (tags allreduce/opt spans).
    param_bytes: u64,
}

impl<'a> Trainer<'a> {
    pub fn new(
        g: &'a ModelGraph,
        pt: &'a Partitioning,
        cfg: EngineConfig,
        ce: &'a CommEngine,
        rt: &'a Runtime,
        data: SyntheticDataset,
    ) -> anyhow::Result<Trainer<'a>> {
        let mode = if cfg.eager_sends { SendMode::Eager } else { SendMode::Blocking };
        let program = Program::compile_with(g, pt, cfg.num_microbatches, cfg.schedule, mode);
        let eval_program = Program::forward_only(pt, cfg.schedule);
        // Under interleaved schedules a rank owns several stages (model
        // chunks); its parameter set is their union, ascending node order
        // (stages ascend and partitions are contiguous chunks).
        let my_nodes: Vec<NodeId> = program
            .stages_of(ce.partition)
            .iter()
            .flat_map(|&s| pt.parts[s].iter().copied())
            .collect();
        // Global parameter ordinal per node: number of parameter slots in
        // all earlier nodes. Seeding init by ordinal (not node id) makes
        // initialization invariant under graph rewrites that preserve the
        // parameter sequence — e.g. conv+bn+relu fusion — so a fused model
        // trains from the same weights as its unfused original.
        let mut ordinal_base = vec![0usize; g.num_nodes()];
        let mut acc = 0usize;
        for (i, node) in g.nodes.iter().enumerate() {
            ordinal_base[i] = acc;
            acc += node.params.len();
        }
        let mut params = HashMap::new();
        let mut param_order = vec![];
        for &n in &my_nodes {
            let node = &g.nodes[n];
            if node.params.is_empty() {
                continue;
            }
            let mut slots = vec![];
            for (si, spec) in node.params.iter().enumerate() {
                // Deterministic init from (seed, param ordinal): every
                // replica computes identical weights, and so does the
                // sequential baseline — the foundation of the equivalence
                // tests.
                let t = if spec.fan_in > 0 {
                    let mut rng = Rng::new(
                        cfg.seed
                            .wrapping_mul(0x1000193)
                            .wrapping_add(((ordinal_base[n] + si) as u64) << 8),
                    );
                    Tensor::he_normal(&spec.dims, spec.fan_in, &mut rng)
                } else if spec.role == "gamma" {
                    Tensor::ones(&spec.dims)
                } else {
                    Tensor::zeros(&spec.dims)
                };
                slots.push(t);
                param_order.push((n, si));
            }
            params.insert(n, slots);
        }
        // Paper-faithful init sync: broadcast from replica 0 (a no-op on the
        // values here since init is deterministic, but exercises the CE path
        // the paper requires).
        let mut bc: Vec<(NodeId, usize)> = param_order.clone();
        bc.sort();
        for (i, (n, si)) in bc.iter().enumerate() {
            let t = &mut params.get_mut(n).unwrap()[*si];
            ce.bcast_param(t, i);
        }
        let opt = SgdMomentum::new(cfg.lr, cfg.momentum, &param_order, &params);
        let param_bytes: u64 = param_order
            .iter()
            .map(|(n, si)| params[n][*si].size_bytes() as u64)
            .sum();
        Ok(Trainer {
            g,
            pt,
            cfg,
            ce,
            rt,
            data,
            params,
            opt,
            program,
            eval_program,
            param_order,
            my_nodes,
            tracer: crate::trace::Tracer::off(),
            param_bytes,
        })
    }

    /// Attach an hftrace recording handle: every interpreted instruction in
    /// subsequent `train_step` calls becomes a typed span. Strictly
    /// observation-only.
    pub fn set_tracer(&mut self, tracer: crate::trace::Tracer) {
        self.tracer = tracer;
    }

    /// The compiled schedule program (shared shape with sim/mem consumers).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Batch size processed per step per replica.
    pub fn replica_batch(&self) -> usize {
        self.cfg.microbatch * self.cfg.num_microbatches
    }

    /// Global sample index base for (step, replica, microbatch).
    fn sample_base(&self, step: u64, mb: usize) -> u64 {
        let ebs = (self.replica_batch() * self.ce.replica.size()) as u64;
        step * ebs
            + (self.ce.replica_id * self.replica_batch()) as u64
            + (mb * self.cfg.microbatch) as u64
    }

    fn is_first_partition(&self) -> bool {
        self.ce.partition == 0
    }

    fn is_last_partition(&self) -> bool {
        // The loss head lives in the last *stage*, which the round-robin
        // stage map puts on the last *rank*.
        self.ce.partition == self.program.num_partitions - 1
    }

    /// Does `stage` run on this rank?
    fn is_my_stage(&self, stage: usize) -> bool {
        stage % self.program.num_partitions == self.ce.partition
    }

    /// Interpret `FwdCompute {node, mb}`: run one node's forward, stash the
    /// output under the node id. Returns the head at the loss node.
    fn exec_fwd_node(
        &self,
        step: u64,
        mb: usize,
        test: bool,
        nid: NodeId,
        acts: &mut HashMap<NodeId, Tensor>,
    ) -> anyhow::Result<Option<Head>> {
        let n_mb = self.cfg.microbatch;
        let base = self.sample_base(step, mb);
        let node = &self.g.nodes[nid];
        let mut head = None;
        // Borrow inputs from the stash (no clones on the hot path; every
        // producer — local or received — is in `acts` by schedule order).
        let inputs: Vec<&Tensor> = node.inputs.iter().map(|src| &acts[src]).collect();
        let out = match &node.kind {
            LayerKind::Input => {
                debug_assert!(self.is_first_partition() || !node.inputs.is_empty());
                let (x, _, _) = if test {
                    self.data.test_batch(base, n_mb)
                } else {
                    self.data.batch(base, n_mb)
                };
                x
            }
            LayerKind::Add => {
                let mut s = inputs[0].clone();
                s.add_assign(inputs[1]);
                s
            }
            LayerKind::Flatten => {
                let t = inputs[0];
                let flat: usize = t.shape.dims()[1..].iter().product();
                Tensor::new(Shape::new(&[t.batch(), flat]), t.data.clone())
            }
            LayerKind::SoftmaxXent => {
                let (_, y, labels) = if test {
                    self.data.test_batch(base, n_mb)
                } else {
                    self.data.batch(base, n_mb)
                };
                let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                    .expect("loss artifact");
                let outs = self.rt.exec(&art.fwd, &[inputs[0], &y])?;
                let loss = outs[0].data[0];
                head = Some((loss, outs[1].clone(), labels));
                // The loss node's "activation" is its glogits (only used
                // locally in backward).
                outs[1].clone()
            }
            _ => {
                let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                    .expect("artifact for compute node");
                // Primitive signature: fwd(x, params...).
                let mut args: Vec<&Tensor> = vec![inputs[0]];
                if let Some(slots) = self.params.get(&nid) {
                    args.extend(slots.iter());
                }
                let outs = self.rt.exec(&art.fwd, &args)?;
                outs.into_iter().next().unwrap()
            }
        };
        acts.insert(nid, out);
        Ok(head)
    }

    /// Interpret `BwdCompute {node, mb}` (and ZB-H1's `BwdInput` when
    /// `split` is set): assemble the node's output-gradient (local
    /// consumers + received errors, already summed into `gout` in
    /// instruction order), compute input and parameter gradients, route
    /// local input-gradients into `gout` and remote ones into
    /// `pending_err` for the following `SendError` ops. With `split`,
    /// parameter gradients are parked in `pending_wgrad` under
    /// `(node, mb)` instead of being accumulated — the matching
    /// `BwdWeight` retires them later.
    #[allow(clippy::too_many_arguments)]
    fn exec_bwd_node(
        &self,
        mb: usize,
        nid: NodeId,
        split: bool,
        acts: &HashMap<NodeId, Tensor>,
        glogits: Option<&Tensor>,
        gout: &mut HashMap<NodeId, Tensor>,
        grads: &mut HashMap<NodeId, Vec<Tensor>>,
        pending_err: &mut HashMap<(usize, usize), Tensor>,
        pending_wgrad: &mut HashMap<(NodeId, usize), Vec<Tensor>>,
    ) -> anyhow::Result<()> {
        let n_mb = self.cfg.microbatch;
        let node = &self.g.nodes[nid];
        debug_assert!(!matches!(node.kind, LayerKind::Input), "Input has no backward");
        // 1) dL/d(out of nid): accumulated by earlier BwdCompute (local
        // consumers) and RecvError (remote consumers) instructions.
        let gy = match &node.kind {
            LayerKind::SoftmaxXent => None, // loss root: uses fwd glogits
            _ => gout.remove(&nid),
        };
        if !matches!(node.kind, LayerKind::SoftmaxXent) && gy.is_none() {
            // Dead-end node (shouldn't happen in validated graphs).
            return Ok(());
        }
        // 2) Input gradients (+ parameter gradients).
        let gins: Vec<(NodeId, Tensor)> = match &node.kind {
            LayerKind::SoftmaxXent => {
                let g = glogits.expect("loss backward needs fwd glogits").clone();
                vec![(node.inputs[0], g)]
            }
            LayerKind::Add => {
                let gy = gy.unwrap();
                vec![(node.inputs[0], gy.clone()), (node.inputs[1], gy)]
            }
            LayerKind::Flatten => {
                let gy = gy.unwrap();
                let src = node.inputs[0];
                let mut dims = vec![gy.batch()];
                dims.extend_from_slice(&self.g.nodes[src].out_shape);
                vec![(src, Tensor::new(Shape(dims), gy.data))]
            }
            kind => {
                let gy = gy.unwrap();
                let art = crate::graph::artifact::node_artifact(self.g, nid, n_mb)
                    .expect("artifact for compute node");
                let bwd = art.bwd.as_ref().expect("non-loss node has bwd");
                // Primitive signatures (model.instance):
                //   conv/bn/dense: bwd(x, <param subset>, gy)
                //   relu/pool:     bwd(x, gy)
                //   gap:           bwd(gy)        (x only matters for shape)
                let slots = self.params.get(&nid);
                let mut args: Vec<&Tensor> = vec![];
                if !matches!(kind, LayerKind::GlobalAvgPool) {
                    args.push(self.node_input_act(nid, acts));
                }
                match kind {
                    LayerKind::Conv3x3 { .. } | LayerKind::Conv1x1 { .. } => {
                        args.push(&slots.unwrap()[0]); // w
                    }
                    LayerKind::ConvBnRelu { .. } => {
                        let s = slots.unwrap();
                        args.extend([&s[0], &s[1], &s[2]]); // w, gamma, beta
                    }
                    LayerKind::BatchNorm => {
                        args.push(&slots.unwrap()[0]); // gamma
                    }
                    LayerKind::Dense { .. } => {
                        args.push(&slots.unwrap()[0]); // w
                    }
                    LayerKind::DenseRelu { .. } => {
                        let s = slots.unwrap();
                        args.extend([&s[0], &s[1]]); // w, b
                    }
                    _ => {}
                }
                args.push(&gy);
                let mut outs = self.rt.exec(bwd, &args)?;
                // outs[0] = gx; outs[1..] = parameter gradients in the
                // same slot order as node.params.
                let gx = outs.remove(0);
                if !outs.is_empty() {
                    if split {
                        pending_wgrad.insert((nid, mb), outs);
                    } else {
                        accumulate_wgrads(grads, nid, &outs);
                    }
                }
                vec![(node.inputs[0], gx)]
            }
        };
        // 3) Route input gradients: accumulate if the producer's stage is
        // on this rank (its own stage or, under interleaved, a sibling
        // chunk — same-rank messages are elided), else park for SendError.
        for (src, gin) in gins {
            if self.is_my_stage(self.pt.assign[src]) {
                match gout.get_mut(&src) {
                    Some(t) => t.add_assign(&gin),
                    None => {
                        gout.insert(src, gin);
                    }
                }
            } else {
                let e = self
                    .pt
                    .edges
                    .iter()
                    .find(|e| e.src_node == src && e.dst_node == nid)
                    .expect("cross edge for backward send");
                pending_err.insert((e.id, mb), gin);
            }
        }
        Ok(())
    }

    /// The stashed input activation of node `nid` (its first input's
    /// output). For cross-partition inputs the schedule stashed the
    /// received tensor under the producer id.
    fn node_input_act<'b>(
        &self,
        nid: NodeId,
        acts: &'b HashMap<NodeId, Tensor>,
    ) -> &'b Tensor {
        let src = self.g.nodes[nid].inputs[0];
        acts.get(&src).expect("input activation stashed")
    }

    /// One full training step: interpret this rank's schedule program.
    /// Returns the replica-local metrics (meaningful on the last
    /// partition).
    pub fn train_step(&mut self, step: u64) -> anyhow::Result<StepMetrics> {
        let t0 = std::time::Instant::now();
        if let Some(s) = &self.cfg.lr_schedule {
            self.opt.lr = s.at(step);
        }
        let m = self.cfg.num_microbatches;
        let mut stashes: Vec<HashMap<NodeId, Tensor>> = (0..m).map(|_| HashMap::new()).collect();
        let mut gouts: Vec<HashMap<NodeId, Tensor>> = (0..m).map(|_| HashMap::new()).collect();
        let mut heads: Vec<Option<Head>> = vec![None; m];
        let mut grads: HashMap<NodeId, Vec<Tensor>> = HashMap::new();
        let mut pending_err: HashMap<(usize, usize), Tensor> = HashMap::new();
        // ZB-H1: parameter gradients parked by BwdInput, retired by
        // BwdWeight. Bounded by the deferral window (<= pipeline depth
        // microbatches of parameter-shaped tensors).
        let mut pending_wgrad: HashMap<(NodeId, usize), Vec<Tensor>> = HashMap::new();
        // Eager sends in flight: handle -> CommEngine send handle. Error
        // payloads live inside the handle until WaitSend (MPI_Isend buffer
        // contract); bounded by Program::peak_in_flight_sends.
        let mut in_flight: HashMap<usize, SendHandle> = HashMap::new();

        // Iterate by index: `Instr` is `Copy`, so this avoids cloning the
        // instruction stream every step while keeping `self` free for the
        // mutating epilogue ops.
        let part = self.ce.partition;
        for i in 0..self.program.rank(part).len() {
            let instr = self.program.rank(part)[i];
            let span = self.tracer.start();
            match instr {
                Instr::FwdCompute { node, mb, .. } => {
                    if let Some(h) = self.exec_fwd_node(step, mb, false, node, &mut stashes[mb])? {
                        heads[mb] = Some(h);
                    }
                }
                Instr::SendActivation { edge, peer, mb } => {
                    let e = &self.pt.edges[edge];
                    let t = &stashes[mb][&e.src_node];
                    self.ce.send_activation(t, peer, edge, mb);
                }
                Instr::RecvActivation { edge, peer, mb } => {
                    let e = &self.pt.edges[edge];
                    let t = self.ce.recv_activation(peer, edge, mb);
                    stashes[mb].insert(e.src_node, t);
                }
                Instr::BwdCompute { node, mb, .. } | Instr::BwdInput { node, mb, .. } => {
                    let split = matches!(instr, Instr::BwdInput { .. });
                    let glogits: Option<&Tensor> = heads[mb].as_ref().map(|(_, g, _)| g);
                    self.exec_bwd_node(
                        mb,
                        node,
                        split,
                        &stashes[mb],
                        glogits,
                        &mut gouts[mb],
                        &mut grads,
                        &mut pending_err,
                        &mut pending_wgrad,
                    )?;
                }
                Instr::BwdWeight { node, mb, .. } => {
                    let outs = pending_wgrad
                        .remove(&(node, mb))
                        .expect("BwdInput parked the weight gradients before BwdWeight");
                    accumulate_wgrads(&mut grads, node, &outs);
                }
                Instr::SendError { edge, peer, mb } => {
                    let t = pending_err
                        .remove(&(edge, mb))
                        .expect("backward computed the partial error before its send");
                    self.ce.send_error(&t, peer, edge, mb);
                }
                Instr::PostSendActivation { edge, peer, mb, handle } => {
                    let e = &self.pt.edges[edge];
                    let t = &stashes[mb][&e.src_node];
                    in_flight.insert(handle, self.ce.post_send_activation(t, peer, edge, mb));
                }
                Instr::PostSendError { edge, peer, mb, handle } => {
                    let t = pending_err
                        .remove(&(edge, mb))
                        .expect("backward computed the partial error before its post");
                    in_flight.insert(handle, self.ce.post_send_error(t, peer, edge, mb));
                }
                Instr::WaitSend { handle } => {
                    let h = in_flight
                        .remove(&handle)
                        .expect("WaitSend pairs with an earlier PostSend");
                    self.ce.wait_send(h);
                }
                Instr::RecvError { edge, peer, mb } => {
                    let e = &self.pt.edges[edge];
                    let err = self.ce.recv_error(peer, edge, mb);
                    match gouts[mb].get_mut(&e.src_node) {
                        Some(t) => t.add_assign(&err),
                        None => {
                            gouts[mb].insert(e.src_node, err);
                        }
                    }
                }
                Instr::DropStash { mb } => {
                    // End of the microbatch's live interval: release the
                    // activation stash and gradient accumulators (the 1F1B
                    // memory bound is realized here, not just modeled).
                    stashes[mb] = HashMap::new();
                    gouts[mb] = HashMap::new();
                }
                Instr::AllreduceGrads => {
                    // Average over microbatches, then data-parallel
                    // allreduce (per-partition communicator, fused).
                    let inv_m = 1.0 / m as f32;
                    for slots in grads.values_mut() {
                        for t in slots.iter_mut() {
                            t.scale(inv_m);
                        }
                    }
                    // Deterministic packing order across replicas.
                    let mut by_node: HashMap<NodeId, &mut Vec<Tensor>> =
                        grads.iter_mut().map(|(k, v)| (*k, v)).collect();
                    let mut staged: Vec<(usize, &mut Tensor)> = vec![];
                    for (i, (n, _si)) in self.param_order.iter().enumerate() {
                        if let Some(slots) = by_node.remove(n) {
                            for (j, t) in slots.iter_mut().enumerate() {
                                staged.push((i * 16 + j, t));
                            }
                        }
                    }
                    staged.sort_by_key(|(k, _)| *k);
                    let mut flat: Vec<&mut Tensor> = staged.into_iter().map(|(_, t)| t).collect();
                    self.ce.allreduce_grads(&mut flat)?;
                }
                Instr::OptStep => {
                    self.opt.step(&self.param_order, &mut self.params, &grads);
                }
            }
            let mb_size = self.cfg.microbatch;
            self.tracer.record(span, || {
                crate::trace::instr_event(self.g, self.pt, mb_size, &instr, self.param_bytes)
            });
        }
        debug_assert!(
            in_flight.is_empty(),
            "eager sends left in flight after the step: {:?}",
            in_flight.keys().collect::<Vec<_>>()
        );

        // ---- metrics (last partition) ----
        let mut metrics = StepMetrics {
            samples: self.replica_batch() * self.ce.replica.size(),
            ..Default::default()
        };
        if self.is_last_partition() {
            let (mut loss_sum, mut correct, mut total) = (0.0f32, 0usize, 0usize);
            for h in heads.iter().flatten() {
                let (loss, glogits, labels) = h;
                loss_sum += loss;
                let (c, t) = accuracy_from_glogits(glogits, labels, self.cfg.microbatch);
                correct += c;
                total += t;
            }
            let mut mtr = Tensor::new(
                Shape::new(&[2]),
                vec![loss_sum / m as f32, correct as f32 / total.max(1) as f32],
            );
            self.ce.allreduce_metrics(&mut mtr)?;
            metrics.loss = mtr.data[0];
            metrics.accuracy = mtr.data[1];
        }
        metrics.step_secs = t0.elapsed().as_secs_f64();
        Ok(metrics)
    }

    /// Forward-only evaluation over `batches` test microbatches —
    /// interprets the forward-only program per batch.
    /// Returns (loss, accuracy) on the last partition.
    pub fn evaluate(&mut self, batches: usize) -> anyhow::Result<StepMetrics> {
        let mut loss_sum = 0.0f32;
        let (mut correct, mut total) = (0usize, 0usize);
        let instrs: Vec<Instr> = self.eval_program.rank(self.ce.partition).to_vec();
        for b in 0..batches {
            let mut acts: HashMap<NodeId, Tensor> = HashMap::new();
            let mut head = None;
            for instr in &instrs {
                match *instr {
                    Instr::FwdCompute { node, mb, .. } => {
                        if let Some(h) = self.exec_fwd_node(b as u64, mb, true, node, &mut acts)? {
                            head = Some(h);
                        }
                    }
                    Instr::SendActivation { edge, peer, mb } => {
                        let e = &self.pt.edges[edge];
                        let t = &acts[&e.src_node];
                        self.ce.send_activation(t, peer, edge, mb);
                    }
                    Instr::RecvActivation { edge, peer, mb } => {
                        let e = &self.pt.edges[edge];
                        let t = self.ce.recv_activation(peer, edge, mb);
                        acts.insert(e.src_node, t);
                    }
                    _ => unreachable!("forward-only program"),
                }
            }
            if let Some((loss, glogits, labels)) = head {
                loss_sum += loss;
                let (c, t) = accuracy_from_glogits(&glogits, &labels, self.cfg.microbatch);
                correct += c;
                total += t;
            }
        }
        let mut metrics = StepMetrics::default();
        if self.is_last_partition() {
            let mut mtr = Tensor::new(
                Shape::new(&[2]),
                vec![
                    loss_sum / batches.max(1) as f32,
                    correct as f32 / total.max(1) as f32,
                ],
            );
            self.ce.allreduce_metrics(&mut mtr)?;
            metrics.loss = mtr.data[0];
            metrics.accuracy = mtr.data[1];
            metrics.samples = total;
        }
        Ok(metrics)
    }

    /// Snapshot of this rank's parameters keyed by (node, slot) — used by
    /// the equivalence tests and checkpoint-style export.
    pub fn export_params(&self) -> Vec<((NodeId, usize), Tensor)> {
        let mut out = vec![];
        for &(n, si) in &self.param_order {
            out.push(((n, si), self.params[&n][si].clone()));
        }
        out
    }

    /// Full resumable training state of this rank: parameters plus
    /// optimizer velocity, tagged with the next step index. Feed it to
    /// [`checkpoint::save_state`] and a fresh trainer's
    /// [`Trainer::restore_state`] to resume bitwise-identically.
    pub fn export_state(&self, next_step: u64) -> checkpoint::TrainState {
        checkpoint::TrainState {
            next_step,
            params: self.export_params(),
            velocity: self.opt.export_velocity(&self.param_order),
        }
    }

    /// Restore parameters and optimizer velocity from a checkpointed
    /// state. Entries for other ranks' shards are ignored; every parameter
    /// this rank owns must be present and shape-compatible.
    pub fn restore_state(&mut self, st: &checkpoint::TrainState) -> anyhow::Result<()> {
        let by_key: HashMap<(NodeId, usize), &Tensor> =
            st.params.iter().map(|(k, t)| (*k, t)).collect();
        for &(n, si) in &self.param_order {
            let t = by_key
                .get(&(n, si))
                .ok_or_else(|| anyhow::anyhow!("checkpoint is missing param ({n}, {si})"))?;
            let w = &mut self.params.get_mut(&n).expect("own param")[si];
            anyhow::ensure!(
                t.shape == w.shape,
                "param ({n}, {si}): checkpoint shape {:?} != expected {:?}",
                t.shape,
                w.shape
            );
            *w = (*t).clone();
        }
        self.opt.restore_velocity(&st.velocity)
    }

    /// Names of the artifacts this rank executes (for warmup) — all of
    /// its stages' nodes.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v = vec![];
        for &n in &self.my_nodes {
            if let Some(a) =
                crate::graph::artifact::node_artifact(self.g, n, self.cfg.microbatch)
            {
                v.push(a.fwd.clone());
                if let Some(b) = a.bwd {
                    v.push(b);
                }
            }
        }
        v
    }
}

/// Accumulate one microbatch's parameter gradients (`outs`, in slot
/// order) into the cross-microbatch accumulators — shared by the fused
/// `BwdCompute` path and ZB-H1's deferred `BwdWeight` so the arithmetic
/// is identical regardless of when the schedule retires the gradients.
fn accumulate_wgrads(grads: &mut HashMap<NodeId, Vec<Tensor>>, nid: NodeId, outs: &[Tensor]) {
    let slot_grads = grads
        .entry(nid)
        .or_insert_with(|| outs.iter().map(|t| Tensor::zeros(t.shape.dims())).collect());
    for (acc, g) in slot_grads.iter_mut().zip(outs.iter()) {
        acc.add_assign(g);
    }
}

/// Recover predictions from the loss node's glogits:
/// glogits = (softmax(logits) - y) / n  =>  probs = glogits * n + y.
/// Since y is one-hot and softmax is monotone, argmax(probs) works directly.
fn accuracy_from_glogits(glogits: &Tensor, labels: &[usize], n_mb: usize) -> (usize, usize) {
    let classes = glogits.shape.dims()[1];
    let mut correct = 0;
    for (i, &l) in labels.iter().enumerate() {
        let row = &glogits.data[i * classes..(i + 1) * classes];
        let mut best = 0;
        let mut best_v = f32::NEG_INFINITY;
        for (c, &g) in row.iter().enumerate() {
            let p = g * n_mb as f32 + if c == l { 1.0 } else { 0.0 };
            if p > best_v {
                best_v = p;
                best = c;
            }
        }
        if best == l {
            correct += 1;
        }
    }
    (correct, labels.len())
}
