//! Checkpointing: save/restore the full model parameter set.
//!
//! Simple self-describing binary format (no serde in the offline build):
//!
//! ```text
//! magic "HFCKPT1\n"
//! u64 count
//! repeat count times:
//!   u64 node, u64 slot, u64 rank, u64 dims[rank], f32 data[numel]
//! ```
//!
//! Model-parallel ranks write/read only their own partition's entries,
//! matching the paper's claim that HyPar-Flow shards all model state.

use crate::graph::NodeId;
use crate::tensor::{Shape, Tensor};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"HFCKPT1\n";

pub type ParamSet = Vec<((NodeId, usize), Tensor)>;

/// Write a parameter set (e.g. `FitResult::params` or a trainer's
/// `export_params`) to `path`.
pub fn save(path: &Path, params: &ParamSet) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    for ((node, slot), t) in params {
        f.write_all(&(*node as u64).to_le_bytes())?;
        f.write_all(&(*slot as u64).to_le_bytes())?;
        f.write_all(&(t.shape.rank() as u64).to_le_bytes())?;
        for &d in t.shape.dims() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // f32 little-endian payload.
        let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

fn read_u64(r: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read a parameter set from `path`.
pub fn load(path: &Path) -> anyhow::Result<ParamSet> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "{path:?}: not a HyPar-Flow checkpoint");
    let count = read_u64(&mut f)?;
    let mut out = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let node = read_u64(&mut f)? as usize;
        let slot = read_u64(&mut f)? as usize;
        let rank = read_u64(&mut f)? as usize;
        anyhow::ensure!(rank <= 8, "implausible tensor rank {rank}");
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u64(&mut f)? as usize);
        }
        let shape = Shape::new(&dims);
        let mut bytes = vec![0u8; shape.numel() * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(((node, slot), Tensor::new(shape, data)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hfckpt_test_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(1);
        let params: ParamSet = vec![
            ((1, 0), Tensor::randn(&[4, 3, 3, 3], 1.0, &mut rng)),
            ((2, 0), Tensor::randn(&[4], 1.0, &mut rng)),
            ((2, 1), Tensor::zeros(&[4])),
            ((7, 0), Tensor::scalar(3.25)),
        ];
        let p = tmp("roundtrip");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(params.len(), back.len());
        for ((ka, ta), (kb, tb)) in params.iter().zip(back.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(ta, tb, "bitwise roundtrip");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn trained_model_roundtrips_through_checkpoint() {
        use crate::api::{fit, Strategy, TrainConfig};
        use crate::graph::zoo;
        let cfg = TrainConfig::new(zoo::mlp(4, &[4], 3), Strategy::Sequential)
            .microbatch(2)
            .steps(3)
            .seed(9);
        let r = fit(&cfg).unwrap();
        let p = tmp("trained");
        save(&p, &r.params).unwrap();
        let back = load(&p).unwrap();
        for ((ka, ta), (kb, tb)) in r.params.iter().zip(back.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(ta.max_abs_diff(tb), 0.0);
        }
        std::fs::remove_file(p).ok();
    }
}
