//! Checkpointing: save/restore model parameters — and, for resumable
//! training, the full per-rank train state (parameters + optimizer
//! velocity + step index).
//!
//! Simple self-describing binary formats (no serde in the offline build):
//!
//! ```text
//! params only:        magic "HFCKPT1\n", entry set
//! train state:        magic "HFCKPT2\n", u64 next_step,
//!                     entry set (params), entry set (velocity)
//! entry set:          u64 count, then count x
//!                       u64 node, u64 slot, u64 rank, u64 dims[rank],
//!                       f32 data[numel]
//! ```
//!
//! Model-parallel ranks write/read only their own partition's entries,
//! matching the paper's claim that HyPar-Flow shards all model state —
//! including the optimizer state, whose sharding falls out of the layer
//! partitioning. Restoring a `TrainState` into a fresh trainer resumes
//! training *bitwise-identical* to the uninterrupted run (momentum
//! velocity carries history, so params alone are not enough) — pinned by
//! `resume_mid_pipeline_is_bitwise_identical` below.

use crate::graph::NodeId;
use crate::tensor::{Shape, Tensor};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"HFCKPT1\n";
const MAGIC_STATE: &[u8; 8] = b"HFCKPT2\n";

pub type ParamSet = Vec<((NodeId, usize), Tensor)>;

/// Full resumable training state of one rank (see `Trainer::export_state`).
#[derive(Clone, Debug)]
pub struct TrainState {
    /// The step index training should resume at (steps completed so far).
    /// Resuming at the right index keeps the dataset's index-deterministic
    /// batches aligned with the uninterrupted run.
    pub next_step: u64,
    pub params: ParamSet,
    pub velocity: ParamSet,
}

fn write_set(f: &mut impl Write, set: &ParamSet) -> anyhow::Result<()> {
    f.write_all(&(set.len() as u64).to_le_bytes())?;
    for ((node, slot), t) in set {
        f.write_all(&(*node as u64).to_le_bytes())?;
        f.write_all(&(*slot as u64).to_le_bytes())?;
        f.write_all(&(t.shape.rank() as u64).to_le_bytes())?;
        for &d in t.shape.dims() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // f32 little-endian payload.
        let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

/// Write a parameter set (e.g. `FitResult::params` or a trainer's
/// `export_params`) to `path`.
pub fn save(path: &Path, params: &ParamSet) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    write_set(&mut f, params)
}

/// Write a full per-rank train state (params + velocity + step) to `path`.
pub fn save_state(path: &Path, state: &TrainState) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC_STATE)?;
    f.write_all(&state.next_step.to_le_bytes())?;
    write_set(&mut f, &state.params)?;
    write_set(&mut f, &state.velocity)
}

fn read_u64(r: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_set(f: &mut impl Read) -> anyhow::Result<ParamSet> {
    let count = read_u64(f)?;
    let mut out = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let node = read_u64(f)? as usize;
        let slot = read_u64(f)? as usize;
        let rank = read_u64(f)? as usize;
        anyhow::ensure!(rank <= 8, "implausible tensor rank {rank}");
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u64(f)? as usize);
        }
        let shape = Shape::new(&dims);
        let mut bytes = vec![0u8; shape.numel() * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(((node, slot), Tensor::new(shape, data)));
    }
    Ok(out)
}

/// Read a parameter set from `path`.
pub fn load(path: &Path) -> anyhow::Result<ParamSet> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "{path:?}: not a HyPar-Flow checkpoint");
    read_set(&mut f)
}

/// Read a full train state from `path`.
pub fn load_state(path: &Path) -> anyhow::Result<TrainState> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(
        &magic == MAGIC_STATE,
        "{path:?}: not a HyPar-Flow train-state checkpoint"
    );
    let next_step = read_u64(&mut f)?;
    let params = read_set(&mut f)?;
    let velocity = read_set(&mut f)?;
    Ok(TrainState { next_step, params, velocity })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hfckpt_test_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(1);
        let params: ParamSet = vec![
            ((1, 0), Tensor::randn(&[4, 3, 3, 3], 1.0, &mut rng)),
            ((2, 0), Tensor::randn(&[4], 1.0, &mut rng)),
            ((2, 1), Tensor::zeros(&[4])),
            ((7, 0), Tensor::scalar(3.25)),
        ];
        let p = tmp("roundtrip");
        save(&p, &params).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(params.len(), back.len());
        for ((ka, ta), (kb, tb)) in params.iter().zip(back.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(ta, tb, "bitwise roundtrip");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"definitely not a checkpoint").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn resume_mid_pipeline_is_bitwise_identical() {
        // The headline resumability guarantee: train 4 steps straight
        // through on a 2-rank 1F1B pipeline, versus train 2 steps,
        // checkpoint the full per-rank state (params + momentum velocity
        // + step index) through the HFCKPT2 file format, rebuild a fresh
        // trainer, restore, and train the remaining 2 steps. Both runs
        // must end with bitwise-identical parameters on every rank —
        // params alone would drift (velocity carries history), and a
        // wrong resume step would desync the index-deterministic dataset.
        use crate::api::default_artifacts_dir;
        use crate::comm::CommEngine;
        use crate::data::SyntheticDataset;
        use crate::engine::{EngineConfig, Trainer};
        use crate::graph::zoo;
        use crate::hfmpi::{AllreduceAlgo, World};
        use crate::partition::Partitioning;
        use crate::runtime::Runtime;
        use crate::schedule::{Program, ScheduleKind, SendMode};

        let g = zoo::mlp(8, &[8, 8, 8], 4);
        let pt = Partitioning::auto(&g, 2).unwrap();
        World::run(2, |world| {
            let cfg = EngineConfig {
                microbatch: 4,
                num_microbatches: 4,
                schedule: ScheduleKind::OneF1B,
                lr: 0.05,
                eager_sends: true,
                ..EngineConfig::default()
            };
            let max_in_flight =
                Program::compile_with(&g, &pt, cfg.num_microbatches, cfg.schedule, SendMode::Eager)
                    .max_in_flight_sends();
            let ce = CommEngine::new(
                world,
                2,
                pt.edges.len(),
                cfg.num_microbatches,
                max_in_flight,
                usize::MAX,
                AllreduceAlgo::Auto,
            );
            let rt = Runtime::open(default_artifacts_dir()).unwrap();
            let data = SyntheticDataset::new(cfg.seed, 4, &[8], 1.0);

            // Uninterrupted baseline.
            let mut a = Trainer::new(&g, &pt, cfg.clone(), &ce, &rt, data.clone()).unwrap();
            for step in 0..4 {
                a.train_step(step).unwrap();
            }
            let want = a.export_params();
            drop(a);

            // Interrupted run: 2 steps, checkpoint to disk, fresh trainer,
            // restore, 2 more steps.
            let mut b = Trainer::new(&g, &pt, cfg.clone(), &ce, &rt, data.clone()).unwrap();
            for step in 0..2 {
                b.train_step(step).unwrap();
            }
            let p = tmp(&format!("resume_r{}", world.rank()));
            save_state(&p, &b.export_state(2)).unwrap();
            drop(b);
            let st = load_state(&p).unwrap();
            std::fs::remove_file(&p).ok();
            assert_eq!(st.next_step, 2);

            let mut c = Trainer::new(&g, &pt, cfg.clone(), &ce, &rt, data.clone()).unwrap();
            c.restore_state(&st).unwrap();
            for step in st.next_step..4 {
                c.train_step(step).unwrap();
            }
            let got = c.export_params();
            assert_eq!(want.len(), got.len());
            for ((ka, ta), (kb, tb)) in want.iter().zip(got.iter()) {
                assert_eq!(ka, kb);
                assert_eq!(
                    ta.max_abs_diff(tb),
                    0.0,
                    "rank {} param {ka:?}: resumed run diverged",
                    world.rank()
                );
            }
        });
    }

    #[test]
    fn trained_model_roundtrips_through_checkpoint() {
        use crate::api::{fit, Strategy, TrainConfig};
        use crate::graph::zoo;
        let cfg = TrainConfig::new(zoo::mlp(4, &[4], 3), Strategy::Sequential)
            .microbatch(2)
            .steps(3)
            .seed(9);
        let r = fit(&cfg).unwrap();
        let p = tmp("trained");
        save(&p, &r.params).unwrap();
        let back = load(&p).unwrap();
        for ((ka, ta), (kb, tb)) in r.params.iter().zip(back.iter()) {
            assert_eq!(ka, kb);
            assert_eq!(ta.max_abs_diff(tb), 0.0);
        }
        std::fs::remove_file(p).ok();
    }
}
