//! SGD with momentum — the optimizer the paper's Keras baselines use
//! (`optimizer.apply_gradients()` in Listing 3). Keras semantics:
//!
//! ```text
//! v <- momentum * v - lr * g
//! w <- w + v
//! ```
//!
//! State (one velocity tensor per parameter) lives on the partition that
//! owns the parameter — the model-parallel sharding of optimizer state falls
//! out of the layer partitioning for free, one of the memory wins §8 counts.

use crate::graph::NodeId;
use crate::tensor::Tensor;
use std::collections::HashMap;

pub struct SgdMomentum {
    pub lr: f32,
    pub momentum: f32,
    velocity: HashMap<(NodeId, usize), Tensor>,
}

impl SgdMomentum {
    pub fn new(
        lr: f32,
        momentum: f32,
        param_order: &[(NodeId, usize)],
        params: &HashMap<NodeId, Vec<Tensor>>,
    ) -> Self {
        let velocity = param_order
            .iter()
            .map(|&(n, si)| ((n, si), Tensor::zeros(params[&n][si].shape.dims())))
            .collect();
        SgdMomentum { lr, momentum, velocity }
    }

    /// Snapshot the velocity tensors in `param_order` — checkpointing the
    /// optimizer state is what makes a resumed run bitwise-identical to an
    /// uninterrupted one (momentum carries history across steps).
    pub fn export_velocity(&self, param_order: &[(NodeId, usize)]) -> Vec<((NodeId, usize), Tensor)> {
        param_order.iter().map(|&k| (k, self.velocity[&k].clone())).collect()
    }

    /// Restore velocity slots from a checkpoint. Entries for parameters
    /// this optimizer does not own are ignored (other ranks' shards); every
    /// owned slot must be present and shape-compatible.
    pub fn restore_velocity(
        &mut self,
        entries: &[((NodeId, usize), Tensor)],
    ) -> anyhow::Result<()> {
        let by_key: HashMap<(NodeId, usize), &Tensor> =
            entries.iter().map(|(k, t)| (*k, t)).collect();
        for (k, v) in self.velocity.iter_mut() {
            let t = by_key
                .get(k)
                .ok_or_else(|| anyhow::anyhow!("checkpoint is missing velocity for {k:?}"))?;
            anyhow::ensure!(
                t.shape == v.shape,
                "velocity {k:?}: checkpoint shape {:?} != expected {:?}",
                t.shape,
                v.shape
            );
            *v = (*t).clone();
        }
        Ok(())
    }

    /// Apply one update. Missing gradient entries (nodes without params)
    /// are skipped.
    pub fn step(
        &mut self,
        param_order: &[(NodeId, usize)],
        params: &mut HashMap<NodeId, Vec<Tensor>>,
        grads: &HashMap<NodeId, Vec<Tensor>>,
    ) {
        for &(n, si) in param_order {
            let Some(gslots) = grads.get(&n) else { continue };
            let g = &gslots[si];
            let v = self.velocity.get_mut(&(n, si)).expect("velocity slot");
            let w = &mut params.get_mut(&n).expect("param slot")[si];
            for ((vi, gi), wi) in v.data.iter_mut().zip(g.data.iter()).zip(w.data.iter_mut()) {
                *vi = self.momentum * *vi - self.lr * *gi;
                *wi += *vi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(lr: f32, mom: f32) -> (SgdMomentum, Vec<(NodeId, usize)>, HashMap<NodeId, Vec<Tensor>>) {
        let order = vec![(1usize, 0usize)];
        let mut params = HashMap::new();
        params.insert(1usize, vec![Tensor::full(&[2], 1.0)]);
        let opt = SgdMomentum::new(lr, mom, &order, &params);
        (opt, order, params)
    }

    #[test]
    fn plain_sgd_without_momentum() {
        let (mut opt, order, mut params) = setup(0.1, 0.0);
        let mut grads = HashMap::new();
        grads.insert(1usize, vec![Tensor::full(&[2], 2.0)]);
        opt.step(&order, &mut params, &grads);
        assert_eq!(params[&1][0].data, vec![0.8; 2]); // 1 - 0.1*2
    }

    #[test]
    fn momentum_accumulates_keras_style() {
        let (mut opt, order, mut params) = setup(0.1, 0.9);
        let mut grads = HashMap::new();
        grads.insert(1usize, vec![Tensor::full(&[2], 1.0)]);
        opt.step(&order, &mut params, &grads);
        // v1 = -0.1, w = 0.9
        assert!((params[&1][0].data[0] - 0.9).abs() < 1e-6);
        opt.step(&order, &mut params, &grads);
        // v2 = 0.9*(-0.1) - 0.1 = -0.19, w = 0.71
        assert!((params[&1][0].data[0] - 0.71).abs() < 1e-6);
    }

    #[test]
    fn missing_grads_leave_params_untouched() {
        let (mut opt, order, mut params) = setup(0.1, 0.9);
        let grads = HashMap::new();
        opt.step(&order, &mut params, &grads);
        assert_eq!(params[&1][0].data, vec![1.0; 2]);
    }
}
