//! The deadlock-free message schedule (paper §6.3).
//!
//! With skip connections, an arbitrary send/recv order can deadlock with
//! rendezvous (synchronous) MPI sends: if Partition-1 sends its skip output
//! to Partition-3 first while Partition-3 is blocked waiting on
//! Partition-2, and Partition-2 is itself blocked on Partition-1, nobody
//! progresses. The paper's rule: *sort the message sequence by rank so each
//! partition sends first to the partition holding the next layer.*
//!
//! This module materializes the complete per-partition schedule (forward
//! sends/recvs + backward error sends/recvs) and provides a **rendezvous
//! deadlock checker** used by tests: it simulates synchronous (unbuffered)
//! send semantics over any schedule and reports whether it completes. The
//! hfmpi fabric itself buffers sends (MPI_Bsend semantics), so the runtime
//! cannot deadlock, but the schedule is kept paper-faithful and the checker
//! proves it — including on randomly generated skip topologies (see
//! `rust/tests/proptests.rs`).

use super::Partitioning;
use crate::graph::NodeId;

/// Direction of a scheduled message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgDir {
    SendActivation,
    RecvActivation,
    SendError,
    RecvError,
}

/// One message slot in a partition's program.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduledMsg {
    pub dir: MsgDir,
    /// Peer partition.
    pub peer: usize,
    /// Cross-edge id (tag component).
    pub edge: usize,
    /// The node whose execution this message precedes/follows.
    pub node: NodeId,
}

/// Per-partition ordered message program for one microbatch.
#[derive(Clone, Debug)]
pub struct MsgSchedule {
    /// `programs[p]` = ordered message ops for partition `p`
    /// (forward pass then backward pass).
    pub programs: Vec<Vec<ScheduledMsg>>,
}

impl MsgSchedule {
    /// Build the deadlock-free schedule for a partitioning.
    ///
    /// Every partition orders its message ops by one **global key**:
    /// forward by `(consumer node, producer node)`, backward by the mirror
    /// `(Reverse(producer), Reverse(consumer))`. Because all programs agree
    /// on a single total order over edges, rendezvous matching always
    /// progresses on the globally-smallest unmatched edge — no circular
    /// wait is possible (inductive argument; fuzzed in proptests.rs).
    ///
    /// This generalizes the paper's §6.3 rule ("send the first message to
    /// the partition which has the next layer"): consumer-order means the
    /// chain edge to the next layer is always sent before a skip edge that
    /// lands further downstream. Naive production-order sends — emitting a
    /// block input's skip before the block body's boundary output — are
    /// exactly what `naive_unsorted_order_would_deadlock` shows wedging.
    ///
    /// Execution validity: a send of edge (s → d) is keyed (d, s), and
    /// every compute of node s happens within key block (s, ·) < (d, ·)
    /// since the graph is topological (s < d), so outputs are always
    /// produced before their sends are scheduled.
    pub fn build(pt: &Partitioning) -> MsgSchedule {
        let p = pt.num_partitions;
        let mut programs: Vec<Vec<ScheduledMsg>> = vec![vec![]; p];

        for part in 0..p {
            // ---- forward: global key (dst_node, src_node) ----
            let mut fwd: Vec<(usize, usize, ScheduledMsg)> = vec![];
            for e in &pt.edges {
                if e.src_part == part {
                    fwd.push((e.dst_node, e.src_node, ScheduledMsg {
                        dir: MsgDir::SendActivation,
                        peer: e.dst_part,
                        edge: e.id,
                        node: e.src_node,
                    }));
                }
                if e.dst_part == part {
                    fwd.push((e.dst_node, e.src_node, ScheduledMsg {
                        dir: MsgDir::RecvActivation,
                        peer: e.src_part,
                        edge: e.id,
                        node: e.dst_node,
                    }));
                }
            }
            fwd.sort_by_key(|&(d, s, _)| (d, s));
            programs[part].extend(fwd.into_iter().map(|(_, _, m)| m));

            // ---- backward: errors flow dst -> src; global key mirrors
            // forward: (Reverse(src_node), Reverse(dst_node)) ----
            let mut bwd: Vec<(usize, usize, ScheduledMsg)> = vec![];
            for e in &pt.edges {
                if e.dst_part == part {
                    bwd.push((e.src_node, e.dst_node, ScheduledMsg {
                        dir: MsgDir::SendError,
                        peer: e.src_part,
                        edge: e.id,
                        node: e.dst_node,
                    }));
                }
                if e.src_part == part {
                    bwd.push((e.src_node, e.dst_node, ScheduledMsg {
                        dir: MsgDir::RecvError,
                        peer: e.dst_part,
                        edge: e.id,
                        node: e.src_node,
                    }));
                }
            }
            bwd.sort_by_key(|&(s, d, _)| (std::cmp::Reverse(s), std::cmp::Reverse(d)));
            programs[part].extend(bwd.into_iter().map(|(_, _, m)| m));
        }
        MsgSchedule { programs }
    }

    /// Simulate the schedule under **rendezvous** (synchronous send)
    /// semantics: a send completes only when the matching recv is posted.
    /// Returns Ok(steps) if all programs complete, Err(stuck partitions)
    /// on deadlock. This is the checker that validates the paper's §6.3
    /// ordering claim.
    pub fn check_rendezvous(&self) -> Result<usize, Vec<usize>> {
        let p = self.programs.len();
        let mut pc = vec![0usize; p]; // program counters
        let mut steps = 0usize;
        loop {
            let mut progressed = false;
            for a in 0..p {
                if pc[a] >= self.programs[a].len() {
                    continue;
                }
                let ma = &self.programs[a][pc[a]];
                let b = ma.peer;
                if pc[b] >= self.programs[b].len() {
                    continue;
                }
                let mb = &self.programs[b][pc[b]];
                // A send matches a recv of the same edge in the opposite
                // direction at the head of both programs.
                let matched = mb.peer == a
                    && mb.edge == ma.edge
                    && matches!(
                        (ma.dir, mb.dir),
                        (MsgDir::SendActivation, MsgDir::RecvActivation)
                            | (MsgDir::RecvActivation, MsgDir::SendActivation)
                            | (MsgDir::SendError, MsgDir::RecvError)
                            | (MsgDir::RecvError, MsgDir::SendError)
                    );
                if matched {
                    pc[a] += 1;
                    pc[b] += 1;
                    steps += 1;
                    progressed = true;
                }
            }
            if pc.iter().enumerate().all(|(i, &c)| c >= self.programs[i].len()) {
                return Ok(steps);
            }
            if !progressed {
                return Err((0..p)
                    .filter(|&i| pc[i] < self.programs[i].len())
                    .collect());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{zoo, ModelGraph};

    #[test]
    fn chain_schedule_completes() {
        let g = zoo::mlp(8, &[8, 8, 8], 4);
        let pt = Partitioning::auto(&g, 3).unwrap();
        let s = MsgSchedule::build(&pt);
        let steps = s.check_rendezvous().unwrap();
        // Each cross edge appears once forward + once backward.
        assert_eq!(steps, pt.edges.len() * 2);
    }

    #[test]
    fn resnet_skip_schedule_is_deadlock_free() {
        let g = zoo::resnet56_v1();
        for p in [2, 3, 4, 8, 13] {
            let pt = Partitioning::auto(&g, p).unwrap();
            let s = MsgSchedule::build(&pt);
            s.check_rendezvous()
                .unwrap_or_else(|stuck| panic!("p={p} deadlocked at {stuck:?}"));
        }
    }

    #[test]
    fn paper_fig6_example_three_partitions() {
        // The paper's Fig 6: a skip from partition 1 over partition 2 into
        // partition 3 (0-indexed: 0 over 1 into 2).
        let mut g = ModelGraph::new("fig6", &[4, 8, 8]);
        let x = g.input();
        let l1 = g.conv3x3(x, 4, 1); // partition 0
        let l2 = g.conv3x3(l1, 4, 1); // partition 1
        let l3 = g.conv3x3(l2, 4, 1); // partition 1
        let l4 = g.add(l3, l1); // partition 2: needs l1 (skip) + l3
        let gp = g.gap(l4);
        let d = g.dense(gp, 2);
        g.loss(d);
        let pt = Partitioning::from_lpp(&g, &[2, 2, 4]).unwrap();
        // l1->l2 (chain), l1->l4 (skip), l3->l4 (chain).
        assert_eq!(pt.edges.len(), 3);
        let s = MsgSchedule::build(&pt);
        s.check_rendezvous().expect("fig6 schedule must not deadlock");
        // Partition 0's sends are ordered nearest-first: to partition 1
        // (next layer) before partition 2 (skip destination).
        let sends: Vec<usize> = s.programs[0]
            .iter()
            .filter(|m| m.dir == MsgDir::SendActivation)
            .map(|m| m.peer)
            .collect();
        assert_eq!(sends, vec![1, 2]);
    }

    #[test]
    fn naive_unsorted_order_would_deadlock() {
        // Construct the pathological order the paper warns about: partition
        // 0 sends the *skip* (to partition 2) before the chain edge (to
        // partition 1). Under rendezvous semantics this wedges: p2 waits on
        // p1, p1 waits on p0, p0 waits on p2.
        let g = {
            let mut g = ModelGraph::new("bad", &[4, 8, 8]);
            let x = g.input();
            let l1 = g.conv3x3(x, 4, 1);
            let l2 = g.conv3x3(l1, 4, 1);
            let l3 = g.conv3x3(l2, 4, 1);
            let l4 = g.add(l3, l1);
            let gp = g.gap(l4);
            let d = g.dense(gp, 2);
            g.loss(d);
            g
        };
        let pt = Partitioning::from_lpp(&g, &[2, 2, 4]).unwrap();
        let mut s = MsgSchedule::build(&pt);
        // Invert partition 0's send order (skip to p2 first) AND partition
        // 2's recv order (chain from p1 first). Now: p0 waits to hand the
        // skip to p2, p2 waits on p1's chain output, p1 waits on p0 — the
        // exact circular wait of paper §6.3.
        let sends: Vec<usize> = s.programs[0]
            .iter()
            .enumerate()
            .filter(|(_, m)| m.dir == MsgDir::SendActivation)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(sends.len(), 2);
        s.programs[0].swap(sends[0], sends[1]);
        let recvs: Vec<usize> = s.programs[2]
            .iter()
            .enumerate()
            .filter(|(_, m)| m.dir == MsgDir::RecvActivation)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(recvs.len(), 2);
        s.programs[2].swap(recvs[0], recvs[1]);
        assert!(
            s.check_rendezvous().is_err(),
            "inconsistent message order should deadlock under rendezvous semantics"
        );
    }

    #[test]
    fn backward_mirrors_forward() {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let s = MsgSchedule::build(&pt);
        for p in 0..4 {
            let fwd_sends = s.programs[p]
                .iter()
                .filter(|m| m.dir == MsgDir::SendActivation)
                .count();
            let bwd_recvs = s.programs[p]
                .iter()
                .filter(|m| m.dir == MsgDir::RecvError)
                .count();
            assert_eq!(fwd_sends, bwd_recvs, "partition {p}");
        }
    }
}
