//! The Load Balancer: compute an LPP (nodes-per-partition) vector that
//! minimizes the bottleneck partition cost — the classic *linear
//! partitioning* problem, solved by binary search on the bottleneck value
//! with a greedy feasibility check (O(n log(sum/eps))), which scales to
//! ResNet-5000-sized graphs where the O(n^2 p) DP would not.
//!
//! Cost per node = forward FLOPs (backward is a uniform 2x multiple, so it
//! does not change the argmin). Two structural constraints the greedy must
//! respect: node 0 (Input) stays on partition 0, the loss node on the last
//! partition — both fall out naturally from contiguity.

use crate::graph::ModelGraph;

/// Per-node balancing costs.
pub(crate) fn node_costs(g: &ModelGraph) -> Vec<f64> {
    (0..g.num_nodes())
        .map(|i| {
            // Small epsilon keeps zero-cost nodes (Input/Flatten) from making
            // partitions of only-free nodes look feasible.
            g.node_cost(i).flops.max(1.0)
        })
        .collect()
}

/// Can `costs` be split into at most `p` contiguous chunks, each with sum
/// <= `cap`? Greedy first-fit is exact for this feasibility question.
fn feasible(costs: &[f64], p: usize, cap: f64) -> bool {
    let mut chunks = 1usize;
    let mut acc = 0.0;
    for &c in costs {
        if c > cap {
            return false;
        }
        if acc + c > cap {
            chunks += 1;
            acc = c;
            if chunks > p {
                return false;
            }
        } else {
            acc += c;
        }
    }
    true
}

/// Split `costs` greedily under `cap`, then rebalance so exactly `p`
/// non-empty chunks come out (the greedy may use fewer).
fn split_with_cap(costs: &[f64], p: usize, cap: f64) -> Vec<usize> {
    let n = costs.len();
    let mut sizes = vec![];
    let mut acc = 0.0;
    let mut count = 0usize;
    for &c in costs {
        // Close the current chunk on overflow (unless we're already on the
        // last allowed chunk, which must absorb the remainder — the cap came
        // from a feasibility check, so this cannot actually overflow it).
        if count > 0 && acc + c > cap && sizes.len() < p - 1 {
            sizes.push(count);
            count = 0;
            acc = 0.0;
        }
        count += 1;
        acc += c;
    }
    sizes.push(count);
    // Pad to exactly p partitions by splitting the largest chunks.
    while sizes.len() < p {
        let (idx, _) = sizes
            .iter()
            .enumerate()
            .filter(|(_, &s)| s >= 2)
            .max_by(|a, b| a.1.cmp(b.1))
            .expect("cannot make p non-empty partitions: too few nodes");
        let s = sizes[idx];
        sizes[idx] = s / 2;
        sizes.insert(idx + 1, s - s / 2);
    }
    debug_assert_eq!(sizes.iter().sum::<usize>(), n);
    sizes
}

/// Compute a balanced LPP for `p` partitions (FLOP-balanced).
pub fn auto_lpp(g: &ModelGraph, p: usize) -> anyhow::Result<Vec<usize>> {
    auto_lpp_weighted(g, p, &node_costs(g))
}

/// Balanced LPP under arbitrary per-node weights (e.g. memory bytes for
/// trainability studies — the expert would hand-tune LPP the same way).
pub fn auto_lpp_weighted(
    g: &ModelGraph,
    p: usize,
    costs: &[f64],
) -> anyhow::Result<Vec<usize>> {
    let n = g.num_nodes();
    anyhow::ensure!(p >= 1, "need at least one partition");
    anyhow::ensure!(costs.len() == n, "weights length {} != nodes {n}", costs.len());
    anyhow::ensure!(
        p <= n,
        "cannot split {n} nodes across {p} partitions \
         (the paper's 'no more partitions than layers' constraint)"
    );
    if p == 1 {
        return Ok(vec![n]);
    }
    let costs = costs.to_vec();
    let costs: Vec<f64> = costs.iter().map(|c| c.max(1.0)).collect();
    let total: f64 = costs.iter().sum();
    let maxc = costs.iter().cloned().fold(0.0, f64::max);
    let (mut lo, mut hi) = (maxc.max(total / p as f64), total);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if feasible(&costs, p, mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(split_with_cap(&costs, p, hi))
}

/// Convert an LPP vector to (start, end) node ranges.
pub fn lpp_to_ranges(lpp: &[usize]) -> Vec<(usize, usize)> {
    let mut out = vec![];
    let mut start = 0;
    for &c in lpp {
        out.push((start, start + c));
        start += c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn feasible_boundaries() {
        let c = [1.0, 1.0, 1.0, 1.0];
        assert!(feasible(&c, 2, 2.0));
        assert!(!feasible(&c, 2, 1.5));
        assert!(feasible(&c, 4, 1.0));
        assert!(!feasible(&c, 1, 3.9));
    }

    #[test]
    fn auto_lpp_sums_and_nonzero() {
        let g = zoo::resnet110_v1();
        for p in [1, 2, 7, 16, 48] {
            let lpp = auto_lpp(&g, p).unwrap();
            assert_eq!(lpp.len(), p);
            assert_eq!(lpp.iter().sum::<usize>(), g.num_nodes());
            assert!(lpp.iter().all(|&c| c > 0), "p={p}: {lpp:?}");
        }
    }

    #[test]
    fn auto_lpp_more_parts_than_nodes_errors() {
        let g = zoo::mlp(4, &[], 2); // 3 nodes
        assert!(auto_lpp(&g, 10).is_err());
    }

    #[test]
    fn p_equals_n_gives_singletons() {
        let g = zoo::mlp(4, &[3, 3], 2); // 5 nodes
        let lpp = auto_lpp(&g, 5).unwrap();
        assert_eq!(lpp, vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn bottleneck_near_optimal_uniform() {
        // Uniform-ish chain: bottleneck should be within 30% of total/p.
        let g = zoo::mlp(256, &[256; 20], 10);
        let costs = node_costs(&g);
        let total: f64 = costs.iter().sum();
        let lpp = auto_lpp(&g, 4).unwrap();
        let ranges = lpp_to_ranges(&lpp);
        let bottleneck = ranges
            .iter()
            .map(|&(a, b)| costs[a..b].iter().sum::<f64>())
            .fold(0.0, f64::max);
        assert!(bottleneck <= total / 4.0 * 1.5, "bottleneck {bottleneck} vs ideal {}", total / 4.0);
    }

    #[test]
    fn ranges_roundtrip() {
        assert_eq!(lpp_to_ranges(&[2, 3, 1]), vec![(0, 2), (2, 5), (5, 6)]);
    }

    #[test]
    fn resnet5000_scale_is_fast() {
        let g = zoo::resnet_v2(4997, &[3, 32, 32], 10);
        let t0 = std::time::Instant::now();
        let lpp = auto_lpp(&g, 96).unwrap();
        assert_eq!(lpp.iter().sum::<usize>(), g.num_nodes());
        assert!(t0.elapsed().as_secs_f64() < 5.0, "balancer too slow");
    }
}
