//! The **Model Generator** and **Load Balancer** (paper §6.1 / Fig 4):
//! turn a [`ModelGraph`] into `P` contiguous partitions, enumerate every
//! cross-partition edge (boundary edges *and* skip connections, Fig 6),
//! build the forward/backward dependency lists, and produce the
//! rank-sorted, deadlock-free message schedule.
//!
//! Partitions are contiguous node ranges in topological order — the same
//! "layers per partition" (LPP) model the paper exposes. The balancer
//! either takes a user LPP vector (expert knob, Listing 2) or solves the
//! classic linear-partitioning problem on the analytic cost model
//! (binary search on the bottleneck + greedy feasibility check).

mod balancer;
mod schedule;

pub use balancer::{auto_lpp, auto_lpp_weighted, lpp_to_ranges};
pub use schedule::{MsgDir, MsgSchedule, ScheduledMsg};

use crate::graph::{LayerKind, ModelGraph, NodeId};

/// A cross-partition data dependency: `src_node`'s output is consumed by
/// `dst_node` living on another partition. Each edge gets a stable id used
/// as the message-tag offset in both passes (activations forward, partial
/// errors backward — the paper's grad-layer channel).
#[derive(Clone, Debug, PartialEq)]
pub struct CrossEdge {
    pub id: usize,
    pub src_node: NodeId,
    pub dst_node: NodeId,
    pub src_part: usize,
    pub dst_part: usize,
}

/// The partitioned model: assignment plus the communication structure.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub num_partitions: usize,
    /// node id -> partition index.
    pub assign: Vec<usize>,
    /// partition -> node ids in topological order.
    pub parts: Vec<Vec<NodeId>>,
    /// All cross-partition edges, ordered by (src_node, dst_node).
    pub edges: Vec<CrossEdge>,
}

impl Partitioning {
    /// Partition `g` into `p` contiguous ranges using the auto balancer.
    pub fn auto(g: &ModelGraph, p: usize) -> anyhow::Result<Partitioning> {
        let lpp = auto_lpp(g, p)?;
        Self::from_lpp(g, &lpp)
    }

    /// Partition `g` with an explicit LPP (nodes per partition) vector —
    /// the paper's expert knob. Must sum to the node count.
    pub fn from_lpp(g: &ModelGraph, lpp: &[usize]) -> anyhow::Result<Partitioning> {
        let n = g.num_nodes();
        let p = lpp.len();
        anyhow::ensure!(p >= 1, "need at least one partition");
        anyhow::ensure!(
            lpp.iter().sum::<usize>() == n,
            "LPP {:?} must sum to the node count {n}", lpp
        );
        anyhow::ensure!(
            lpp.iter().all(|&c| c > 0),
            "every partition needs at least one node, got {:?}", lpp
        );
        let mut assign = vec![0usize; n];
        let mut parts: Vec<Vec<NodeId>> = vec![vec![]; p];
        let mut next = 0usize;
        for (part, &count) in lpp.iter().enumerate() {
            for _ in 0..count {
                assign[next] = part;
                parts[part].push(next);
                next += 1;
            }
        }
        // Enumerate cross edges in deterministic (src, dst) order.
        let mut edges = vec![];
        for node in &g.nodes {
            for &src in &node.inputs {
                if assign[src] != assign[node.id] {
                    edges.push(CrossEdge {
                        id: edges.len(),
                        src_node: src,
                        dst_node: node.id,
                        src_part: assign[src],
                        dst_part: assign[node.id],
                    });
                }
            }
        }
        edges.sort_by_key(|e| (e.src_node, e.dst_node));
        for (i, e) in edges.iter_mut().enumerate() {
            e.id = i;
        }
        let pt = Partitioning { num_partitions: p, assign, parts, edges };
        pt.check(g)?;
        Ok(pt)
    }

    /// Sanity invariants (also exercised by the proptest fuzzer).
    fn check(&self, g: &ModelGraph) -> anyhow::Result<()> {
        anyhow::ensure!(self.assign[0] == 0, "Input node must be on partition 0");
        if let Some(l) = g.loss_node() {
            anyhow::ensure!(
                self.assign[l] == self.num_partitions - 1,
                "loss node must be on the last partition (got {})",
                self.assign[l]
            );
        }
        // Contiguity <=> assignment is monotone non-decreasing.
        for w in self.assign.windows(2) {
            anyhow::ensure!(w[0] <= w[1], "partition assignment not contiguous");
        }
        Ok(())
    }

    /// Edges whose producer lives on partition `p` (forward sends),
    /// rank-sorted: emitted in node order, nearest destination partition
    /// first (the paper's deadlock-avoidance order, §6.3).
    pub fn sends_of(&self, p: usize) -> Vec<&CrossEdge> {
        let mut v: Vec<&CrossEdge> =
            self.edges.iter().filter(|e| e.src_part == p).collect();
        v.sort_by_key(|e| (e.src_node, e.dst_part, e.dst_node));
        v
    }

    /// Edges whose consumer lives on partition `p` (forward receives),
    /// in consumer-topological order.
    pub fn recvs_of(&self, p: usize) -> Vec<&CrossEdge> {
        let mut v: Vec<&CrossEdge> =
            self.edges.iter().filter(|e| e.dst_part == p).collect();
        v.sort_by_key(|e| (e.dst_node, e.src_node));
        v
    }

    /// Cross edges delivering inputs of `node` (in input-slot order).
    pub fn in_edges_of_node(&self, node: NodeId) -> Vec<&CrossEdge> {
        self.edges.iter().filter(|e| e.dst_node == node).collect()
    }

    /// Cross edges consuming `node`'s output.
    pub fn out_edges_of_node(&self, node: NodeId) -> Vec<&CrossEdge> {
        self.edges.iter().filter(|e| e.src_node == node).collect()
    }

    /// The paper's Fig 6 "Forward list": for partition `p`, the per-node
    /// list of (node, remote destination partitions) it must send to.
    pub fn forward_list(&self, p: usize) -> Vec<(NodeId, Vec<usize>)> {
        let mut out: Vec<(NodeId, Vec<usize>)> = vec![];
        for &n in &self.parts[p] {
            let dsts: Vec<usize> = {
                let mut d: Vec<usize> = self
                    .out_edges_of_node(n)
                    .iter()
                    .map(|e| e.dst_part)
                    .collect();
                d.sort();
                d.dedup();
                d
            };
            if !dsts.is_empty() {
                out.push((n, dsts));
            }
        }
        out
    }

    /// The paper's Fig 6 "Backward list": for partition `p`, the per-node
    /// list of (node, remote source partitions) it receives from.
    pub fn backward_list(&self, p: usize) -> Vec<(NodeId, Vec<usize>)> {
        let mut out: Vec<(NodeId, Vec<usize>)> = vec![];
        for &n in &self.parts[p] {
            let srcs: Vec<usize> = {
                let mut s: Vec<usize> = self
                    .in_edges_of_node(n)
                    .iter()
                    .map(|e| e.src_part)
                    .collect();
                s.sort();
                s.dedup();
                s
            };
            if !srcs.is_empty() {
                out.push((n, srcs));
            }
        }
        out
    }

    /// Total bytes crossing partition boundaries per sample in the forward
    /// pass (used by the simulator and the balancer diagnostics).
    pub fn boundary_bytes_per_sample(&self, g: &ModelGraph) -> usize {
        self.edges
            .iter()
            .map(|e| g.nodes[e.src_node].out_shape.iter().product::<usize>() * 4)
            .sum()
    }

    /// Parameter count on partition `p`.
    pub fn params_of(&self, g: &ModelGraph, p: usize) -> usize {
        self.parts[p]
            .iter()
            .flat_map(|&n| g.nodes[n].params.iter())
            .map(|ps| ps.numel())
            .sum()
    }
}

/// Skip-connection-aware helper: does this graph have non-consecutive
/// connections (paper §4.3)?
pub fn has_skip_connections(g: &ModelGraph) -> bool {
    g.nodes.iter().any(|n| matches!(n.kind, LayerKind::Add))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn from_lpp_basic() {
        let g = zoo::mlp(8, &[6, 5], 4); // input + 2 dense_relu + dense + loss
        assert_eq!(g.num_nodes(), 5);
        let pt = Partitioning::from_lpp(&g, &[2, 2, 1]).unwrap();
        assert_eq!(pt.assign, vec![0, 0, 1, 1, 2]);
        assert_eq!(pt.parts[1], vec![2, 3]);
        // Chain graph: boundary edges only.
        assert_eq!(pt.edges.len(), 2);
        assert_eq!(pt.edges[0].src_node, 1);
        assert_eq!(pt.edges[0].dst_node, 2);
    }

    #[test]
    fn lpp_must_sum() {
        let g = zoo::mlp(8, &[6], 4);
        assert!(Partitioning::from_lpp(&g, &[1, 1]).is_err());
        assert!(Partitioning::from_lpp(&g, &[4, 0]).is_err());
    }

    #[test]
    fn skip_connections_become_cross_edges() {
        let g = zoo::resnet20_v1();
        let p = Partitioning::auto(&g, 4).unwrap();
        assert!(has_skip_connections(&g));
        assert!(
            p.edges.len() >= 3,
            "expected chain + skip cross edges, got {:?}", p.edges.len()
        );
        // Every edge's endpoints agree with the assignment.
        for e in &p.edges {
            assert_eq!(p.assign[e.src_node], e.src_part);
            assert_eq!(p.assign[e.dst_node], e.dst_part);
            assert_ne!(e.src_part, e.dst_part);
        }
    }

    #[test]
    fn single_partition_has_no_edges() {
        let g = zoo::resnet20_v1();
        let p = Partitioning::auto(&g, 1).unwrap();
        assert!(p.edges.is_empty());
        assert_eq!(p.parts[0].len(), g.num_nodes());
    }

    #[test]
    fn forward_backward_lists_mirror() {
        let g = zoo::resnet20_v1();
        let p = Partitioning::auto(&g, 3).unwrap();
        let sends: usize = (0..3).map(|i| p.sends_of(i).len()).sum();
        let recvs: usize = (0..3).map(|i| p.recvs_of(i).len()).sum();
        assert_eq!(sends, recvs);
        assert_eq!(sends, p.edges.len());
    }

    #[test]
    fn auto_balances_within_2x() {
        let g = zoo::resnet56_v1();
        for parts in [2, 4, 8] {
            let p = Partitioning::auto(&g, parts).unwrap();
            let costs: Vec<f64> = (0..parts)
                .map(|i| p.parts[i].iter().map(|&n| g.node_cost(n).flops).sum())
                .collect();
            let max = costs.iter().cloned().fold(0.0, f64::max);
            let avg = costs.iter().sum::<f64>() / parts as f64;
            assert!(max < 2.0 * avg, "parts={parts} costs={costs:?}");
        }
    }

    #[test]
    fn params_partition_sums_to_total() {
        let g = zoo::resnet20_v1();
        let p = Partitioning::auto(&g, 4).unwrap();
        let total: usize = (0..4).map(|i| p.params_of(&g, i)).sum();
        assert_eq!(total, g.num_params());
    }
}
