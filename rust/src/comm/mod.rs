//! The **Communication Engine** (paper §6.3 / Fig 4): the thin,
//! runtime-agnostic facade the Trainer uses — `send`, `recv`, `broadcast`,
//! `allreduce` — specialized to the training roles:
//!
//! - activations forward / partial errors backward on cross-partition
//!   edges (tag = role + edge id + microbatch),
//! - gradient `allreduce` across model replicas (one communicator per
//!   model-partition, the paper's §5.3 layout, with Horovod-style fusion),
//! - initial weight `broadcast` from replica 0.
//!
//! Rank layout: world size = partitions x replicas, with
//! `rank = replica * P + partition`. `pipeline` is the per-replica
//! communicator (indexes == partition ids); `replica` is the per-partition
//! communicator across replicas (indexes == replica ids) on which the 48
//! concurrent allreduces of the paper's ResNet-1001 example run.

use crate::hfmpi::{tags, AllreduceAlgo, Comm, FusionBuffer, SendReq};
use crate::tensor::Tensor;
use crate::trace::{Event, EventKind, Tracer};
use std::cell::RefCell;
use std::collections::HashMap;

/// Maximum microbatches per step encodable in a tag.
pub const MAX_MB: u64 = 4096;

/// Maximum cross-partition edge ids encodable without colliding with the
/// next tag class: the ACTIVATION and ERROR windows are `1 << 20` apart
/// (see `hfmpi::tags`), and each edge consumes `MAX_MB` tags.
pub const MAX_EDGES: u64 = (tags::ERROR - tags::ACTIVATION) / MAX_MB;

/// An eager send in flight: posted via
/// [`CommEngine::post_send_activation`]/[`CommEngine::post_send_error`],
/// completed by [`CommEngine::wait_send`]. Error payloads are owned here
/// until the wait — the MPI_Isend pinned-buffer contract — while
/// activation payloads alias the trainer's stash (live until `DropStash`,
/// which the schedule places after the wait). Under the rendezvous
/// transport the wait genuinely blocks until the receiver consumed the
/// payload, so the pin spans the message's whole in-flight lifetime.
#[must_use = "complete the send with CommEngine::wait_send"]
pub struct SendHandle {
    class: u8,
    edge: usize,
    mb: usize,
    _buf: Option<Tensor>,
    req: SendReq,
}

/// Per-rank communication engine.
pub struct CommEngine {
    /// Within one model replica: member i == partition i.
    pub pipeline: Comm,
    /// Across replicas for this partition: member j == replica j.
    pub replica: Comm,
    pub partition: usize,
    pub replica_id: usize,
    fusion: FusionBuffer,
    /// Declared worst-case concurrently in-flight eager sends (from
    /// `Program::max_in_flight_sends`), enforced at post time.
    max_in_flight: usize,
    /// Live eager sends by (class, edge, mb) tag — each tag may carry at
    /// most one in-flight message at a time, or payloads would alias.
    in_flight: RefCell<HashMap<(u8, usize, usize), ()>>,
    /// hftrace handle recording `comm.*` sub-spans (off by default).
    tracer: RefCell<Tracer>,
}

impl CommEngine {
    /// Split the world communicator into the hybrid-parallel layout.
    /// `world.size()` must equal `partitions * replicas`.
    ///
    /// `num_edges` and `num_microbatches` are the run's tag-space budget:
    /// the (edge, microbatch) pair is packed into a message tag as
    /// `edge * MAX_MB + mb` inside a `1 << 20`-wide class window, so a run
    /// exceeding either limit would silently alias tags between edges (or
    /// between the activation and error classes) and deliver tensors to the
    /// wrong receive. Assert it here, at construction, instead.
    ///
    /// `max_in_flight` declares the worst-case *concurrently* in-flight
    /// eager sends on this rank (`Program::max_in_flight_sends`). Each
    /// concurrent message needs its own distinct (class, edge, microbatch)
    /// tag — there are `2 * num_edges * num_microbatches` of those — so a
    /// declaration exceeding that count proves some tag would carry two
    /// live messages at once. The per-edge/per-mb caps above are not
    /// enough once sends overlap, which is why this is checked separately
    /// (and re-checked per tag at post time).
    pub fn new(
        world: &Comm,
        partitions: usize,
        num_edges: usize,
        num_microbatches: usize,
        max_in_flight: usize,
        fusion_threshold: usize,
        algo: AllreduceAlgo,
    ) -> CommEngine {
        assert!(world.size() % partitions == 0,
                "world size {} not divisible by partitions {partitions}",
                world.size());
        assert!(
            (num_microbatches as u64) <= MAX_MB,
            "num_microbatches {num_microbatches} exceeds the tag budget \
             MAX_MB={MAX_MB}; edge/microbatch tags would alias"
        );
        assert!(
            (num_edges as u64) <= MAX_EDGES,
            "{num_edges} cross-partition edges exceed the tag budget \
             MAX_EDGES={MAX_EDGES}; activation tags would spill into the \
             error tag window"
        );
        let distinct_tags = 2 * num_edges as u64 * num_microbatches as u64;
        assert!(
            max_in_flight as u64 <= distinct_tags,
            "{max_in_flight} concurrently in-flight eager sends exceed the \
             {distinct_tags} distinct (class, edge, microbatch) tags of this \
             run; by pigeonhole some tag would carry two live messages and \
             alias payloads"
        );
        let rank = world.rank();
        let partition = rank % partitions;
        let replica_id = rank / partitions;
        let pipeline = world.split(replica_id as i64, partition as i64);
        let replica = world.split(partition as i64, replica_id as i64);
        CommEngine {
            pipeline,
            replica,
            partition,
            replica_id,
            fusion: FusionBuffer::new(fusion_threshold, algo),
            max_in_flight,
            in_flight: RefCell::new(HashMap::new()),
            tracer: RefCell::new(Tracer::off()),
        }
    }

    /// Attach an hftrace handle: transport-level send/recv/wait/allreduce
    /// sub-spans will be recorded (nested inside the Trainer's IR spans).
    pub fn attach_tracer(&self, tracer: Tracer) {
        *self.tracer.borrow_mut() = tracer;
    }

    fn act_tag(edge: usize, mb: usize) -> u64 {
        debug_assert!((edge as u64) < MAX_EDGES && (mb as u64) < MAX_MB);
        tags::ACTIVATION + edge as u64 * MAX_MB + mb as u64
    }

    fn err_tag(edge: usize, mb: usize) -> u64 {
        debug_assert!((edge as u64) < MAX_EDGES && (mb as u64) < MAX_MB);
        tags::ERROR + edge as u64 * MAX_MB + mb as u64
    }

    /// Forward: ship an activation along cross edge `edge` for microbatch
    /// `mb` to partition `dst`.
    pub fn send_activation(&self, t: &Tensor, dst: usize, edge: usize, mb: usize) {
        debug_assert!((mb as u64) < MAX_MB);
        let tr = self.tracer.borrow();
        let span = tr.start();
        self.pipeline.send(t, dst, Self::act_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommSend).label("act").edge(edge).peer(dst).mb(mb).bytes(bytes)
        });
    }

    pub fn recv_activation(&self, src: usize, edge: usize, mb: usize) -> Tensor {
        let tr = self.tracer.borrow();
        let span = tr.start();
        let t = self.pipeline.recv(src, Self::act_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommRecv).label("act").edge(edge).peer(src).mb(mb).bytes(bytes)
        });
        t
    }

    /// Backward: ship a partial error (the paper's grad-layer payload,
    /// Eq. 6) back along cross edge `edge`.
    pub fn send_error(&self, t: &Tensor, dst: usize, edge: usize, mb: usize) {
        let tr = self.tracer.borrow();
        let span = tr.start();
        self.pipeline.send(t, dst, Self::err_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommSend).label("err").edge(edge).peer(dst).mb(mb).bytes(bytes)
        });
    }

    pub fn recv_error(&self, src: usize, edge: usize, mb: usize) -> Tensor {
        let tr = self.tracer.borrow();
        let span = tr.start();
        let t = self.pipeline.recv(src, Self::err_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommRecv).label("err").edge(edge).peer(src).mb(mb).bytes(bytes)
        });
        t
    }

    /// Eager activation send (MPI_Isend): post the transfer and return
    /// immediately. The payload aliases the caller's stash, which the
    /// schedule keeps live until the paired [`CommEngine::wait_send`].
    pub fn post_send_activation(
        &self,
        t: &Tensor,
        dst: usize,
        edge: usize,
        mb: usize,
    ) -> SendHandle {
        debug_assert!((mb as u64) < MAX_MB);
        let tr = self.tracer.borrow();
        let span = tr.start();
        self.note_posted(0, edge, mb);
        let req = self.pipeline.isend(t, dst, Self::act_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommSend)
                .label("post act")
                .edge(edge)
                .peer(dst)
                .mb(mb)
                .bytes(bytes)
        });
        SendHandle { class: 0, edge, mb, _buf: None, req }
    }

    /// Eager error send: the handle takes ownership of the payload and
    /// pins it until the wait (errors have no stash home to alias).
    pub fn post_send_error(&self, t: Tensor, dst: usize, edge: usize, mb: usize) -> SendHandle {
        debug_assert!((mb as u64) < MAX_MB);
        let tr = self.tracer.borrow();
        let span = tr.start();
        self.note_posted(1, edge, mb);
        let req = self.pipeline.isend(&t, dst, Self::err_tag(edge, mb));
        let bytes = t.size_bytes() as u64;
        tr.record(span, || {
            Event::span(EventKind::CommSend)
                .label("post err")
                .edge(edge)
                .peer(dst)
                .mb(mb)
                .bytes(bytes)
        });
        SendHandle { class: 1, edge, mb, _buf: Some(t), req }
    }

    /// Complete an eager send: blocks until the transfer is done (free on
    /// the buffered transport, a real wait for the matching recv under
    /// rendezvous — the recorded `CommWait` span measures it), releases
    /// the pinned payload, and retires the tag from the in-flight
    /// accounting.
    pub fn wait_send(&self, h: SendHandle) {
        let tr = self.tracer.borrow();
        let span = tr.start();
        let SendHandle { class, edge, mb, _buf, req } = h;
        let bytes = self.pipeline.wait(req);
        self.in_flight.borrow_mut().remove(&(class, edge, mb));
        tr.record(span, || {
            Event::span(EventKind::CommWait)
                .label(if class == 0 { "act" } else { "err" })
                .edge(edge)
                .mb(mb)
                .bytes(bytes)
        });
        // _buf drops here — the send buffer is released.
    }

    /// Current number of eager sends in flight on this rank.
    pub fn in_flight_sends(&self) -> usize {
        self.in_flight.borrow().len()
    }

    fn note_posted(&self, class: u8, edge: usize, mb: usize) {
        let mut live = self.in_flight.borrow_mut();
        assert!(
            live.insert((class, edge, mb), ()).is_none(),
            "eager send already in flight on tag (class {class}, edge {edge}, mb {mb}): \
             a second concurrent message on one tag would alias payloads"
        );
        assert!(
            live.len() <= self.max_in_flight,
            "{} concurrently in-flight eager sends exceed the declared budget {} — \
             the schedule's max_in_flight_sends() and the engine disagree",
            live.len(),
            self.max_in_flight
        );
    }

    /// Data-parallel gradient averaging across this partition's replicas
    /// (fused). No-op for a single replica. Returns allreduce call count.
    pub fn allreduce_grads(&self, grads: &mut [&mut Tensor]) -> anyhow::Result<usize> {
        if self.replica.size() == 1 {
            return Ok(0);
        }
        let tr = self.tracer.borrow();
        let span = tr.start();
        let bytes: u64 = grads.iter().map(|t| t.size_bytes() as u64).sum();
        let n = self.fusion.allreduce_mean(&self.replica, grads)?;
        tr.record(span, || Event::span(EventKind::CommAllreduce).label("grads").bytes(bytes));
        Ok(n)
    }

    /// Broadcast initial weights from replica 0 (paper's CE `broadcast`).
    pub fn bcast_param(&self, t: &mut Tensor, param_id: usize) {
        if self.replica.size() == 1 {
            return;
        }
        let _ = param_id; // id kept for trace symmetry with MPI_Bcast tags
        let tr = self.tracer.borrow();
        let span = tr.start();
        self.replica.bcast(t, 0);
        let bytes = t.size_bytes() as u64;
        tr.record(span, || Event::span(EventKind::CommBcast).label("param").bytes(bytes));
    }

    /// Mean-reduce a metrics vector across replicas (loss/accuracy logging).
    pub fn allreduce_metrics(&self, t: &mut Tensor) -> anyhow::Result<()> {
        if self.replica.size() == 1 {
            return Ok(());
        }
        let tr = self.tracer.borrow();
        let span = tr.start();
        let bytes = t.size_bytes() as u64;
        self.replica.allreduce_mean(t)?;
        tr.record(span, || Event::span(EventKind::CommAllreduce).label("metrics").bytes(bytes));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hfmpi::{Transport, World};

    #[test]
    fn hybrid_layout_2x3() {
        // 3 partitions x 2 replicas = 6 ranks.
        World::run(6, |world| {
            let ce = CommEngine::new(world, 3, 8, 4, 0, usize::MAX, AllreduceAlgo::Auto);
            assert_eq!(ce.partition, world.rank() % 3);
            assert_eq!(ce.replica_id, world.rank() / 3);
            assert_eq!(ce.pipeline.size(), 3);
            assert_eq!(ce.replica.size(), 2);
            assert_eq!(ce.pipeline.rank(), ce.partition);
            assert_eq!(ce.replica.rank(), ce.replica_id);
        });
    }

    #[test]
    fn activations_flow_within_replica_only() {
        World::run(4, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 0, usize::MAX, AllreduceAlgo::Auto);
            // Partition 0 of each replica sends a replica-stamped tensor to
            // partition 1; the receiver must see its own replica's value.
            if ce.partition == 0 {
                let t = Tensor::full(&[2], ce.replica_id as f32);
                ce.send_activation(&t, 1, 0, 0);
            } else {
                let t = ce.recv_activation(0, 0, 0);
                assert_eq!(t.data, vec![ce.replica_id as f32; 2]);
            }
        });
    }

    #[test]
    fn grads_average_across_replicas_per_partition() {
        World::run(4, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 0, usize::MAX, AllreduceAlgo::Auto);
            let mut g = Tensor::full(&[4], (ce.replica_id * 10 + ce.partition) as f32);
            ce.allreduce_grads(&mut [&mut g]).unwrap();
            // replicas {0,1}: values p and 10+p -> mean 5+p.
            assert_eq!(g.data, vec![5.0 + ce.partition as f32; 4]);
        });
    }

    #[test]
    fn errors_and_activations_do_not_collide() {
        // Facing *blocking* sends: buffered-only by design (this exact
        // pattern is the rendezvous deadlock canary in the fabric tests).
        World::run_with_transport(2, Transport::Buffered, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 0, usize::MAX, AllreduceAlgo::Auto);
            if ce.partition == 0 {
                ce.send_activation(&Tensor::scalar(1.0), 1, 5, 3);
                let e = ce.recv_error(1, 5, 3);
                assert_eq!(e.data[0], 2.0);
            } else {
                ce.send_error(&Tensor::scalar(2.0), 0, 5, 3);
                let a = ce.recv_activation(0, 5, 3);
                assert_eq!(a.data[0], 1.0);
            }
        });
    }

    #[test]
    #[should_panic(expected = "exceeds the tag budget")]
    fn too_many_microbatches_rejected_at_construction() {
        World::run(1, |world| {
            CommEngine::new(world, 1, 4, MAX_MB as usize + 1, 0, usize::MAX, AllreduceAlgo::Auto);
        });
    }

    #[test]
    #[should_panic(expected = "exceed the tag budget")]
    fn too_many_edges_rejected_at_construction() {
        World::run(1, |world| {
            CommEngine::new(world, 1, MAX_EDGES as usize + 1, 1, 0, usize::MAX, AllreduceAlgo::Auto);
        });
    }

    #[test]
    fn budget_boundary_is_accepted() {
        World::run(1, |world| {
            CommEngine::new(
                world,
                1,
                MAX_EDGES as usize,
                MAX_MB as usize,
                0,
                usize::MAX,
                AllreduceAlgo::Auto,
            );
        });
    }

    #[test]
    fn eager_post_wait_round_trips() {
        World::run(2, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 4, usize::MAX, AllreduceAlgo::Auto);
            if ce.partition == 0 {
                // Two eager sends in flight at once on distinct tags.
                let a = Tensor::full(&[2], 1.0);
                let h0 = ce.post_send_activation(&a, 1, 0, 0);
                let h1 = ce.post_send_error(Tensor::full(&[2], 2.0), 1, 0, 1);
                assert_eq!(ce.in_flight_sends(), 2);
                ce.wait_send(h0);
                ce.wait_send(h1);
                assert_eq!(ce.in_flight_sends(), 0);
            } else {
                assert_eq!(ce.recv_activation(0, 0, 0).data, vec![1.0; 2]);
                assert_eq!(ce.recv_error(0, 0, 1).data, vec![2.0; 2]);
            }
        });
    }

    #[test]
    fn eager_post_wait_round_trips_under_rendezvous() {
        // The engine's post/wait path on the live rendezvous fabric:
        // posts must not block, waits complete once the receiver drains.
        World::run_with_transport(2, Transport::Rendezvous, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 4, usize::MAX, AllreduceAlgo::Auto);
            if ce.partition == 0 {
                let a = Tensor::full(&[2], 1.0);
                let h0 = ce.post_send_activation(&a, 1, 0, 0);
                let h1 = ce.post_send_error(Tensor::full(&[2], 2.0), 1, 0, 1);
                assert_eq!(ce.in_flight_sends(), 2, "posts must not block under rendezvous");
                ce.wait_send(h0);
                ce.wait_send(h1);
                assert_eq!(ce.in_flight_sends(), 0);
            } else {
                assert_eq!(ce.recv_activation(0, 0, 0).data, vec![1.0; 2]);
                assert_eq!(ce.recv_error(0, 0, 1).data, vec![2.0; 2]);
            }
        });
    }

    #[test]
    #[should_panic(expected = "already in flight")]
    fn double_post_on_one_tag_panics() {
        World::run(1, |world| {
            let ce = CommEngine::new(world, 1, 8, 4, 8, usize::MAX, AllreduceAlgo::Auto);
            let t = Tensor::scalar(1.0);
            let _h0 = ce.post_send_activation(&t, 0, 3, 1);
            let _h1 = ce.post_send_activation(&t, 0, 3, 1); // same tag, no wait
        });
    }

    #[test]
    #[should_panic(expected = "exceed the declared budget")]
    fn post_beyond_declared_in_flight_budget_panics() {
        World::run(1, |world| {
            let ce = CommEngine::new(world, 1, 8, 4, 1, usize::MAX, AllreduceAlgo::Auto);
            let t = Tensor::scalar(1.0);
            let _h0 = ce.post_send_activation(&t, 0, 0, 0);
            let _h1 = ce.post_send_activation(&t, 0, 1, 0); // budget is 1
        });
    }

    #[test]
    #[should_panic(expected = "pigeonhole")]
    fn in_flight_budget_overflowing_the_tag_space_rejected_at_construction() {
        // Regression for the old accounting, which assumed at most one
        // outstanding message per edge/microbatch and accepted any
        // concurrency: declaring more concurrent in-flight sends than
        // there are distinct (class, edge, mb) tags must fail fast.
        World::run(1, |world| {
            CommEngine::new(world, 1, 2, 3, 2 * 2 * 3 + 1, usize::MAX, AllreduceAlgo::Auto);
        });
    }

    #[test]
    fn in_flight_budget_boundary_is_accepted() {
        World::run(1, |world| {
            CommEngine::new(world, 1, 2, 3, 2 * 2 * 3, usize::MAX, AllreduceAlgo::Auto);
        });
    }

    #[test]
    fn bcast_param_syncs_replicas() {
        World::run(4, |world| {
            let ce = CommEngine::new(world, 2, 8, 4, 0, usize::MAX, AllreduceAlgo::Auto);
            let mut w = if ce.replica_id == 0 {
                Tensor::full(&[3], 42.0)
            } else {
                Tensor::zeros(&[3])
            };
            ce.bcast_param(&mut w, 0);
            assert_eq!(w.data, vec![42.0; 3]);
        });
    }
}
