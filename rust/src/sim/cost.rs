//! Per-primitive compute cost model.
//!
//! `t(W, c) = g0 + g1*c + W / (core_rate * ceff(W, c))`
//!
//! Two mechanisms, both measured pathologies of TF-1.13-era CPU training:
//!
//! 1. **Thread-pool fork/join overhead grows with the cores an op spans**
//!    (`g0 + g1*c`). A sequential process gives every op all 48 cores, so
//!    every op pays the widest synchronization cost; a model-parallel
//!    partition with 48/P cores pays far less per op while the pipeline
//!    keeps all cores busy. This is what makes HF(MP) beat sequential even
//!    at batch size 1 (paper Figs 7-10) and makes the gain grow with depth
//!    (ResNet-1001's 3,000+ ops amplify per-op overhead).
//! 2. **Saturating intra-op scaling**: `ceff(W, c) = min(c, 1 + W/grain,
//!    max_intra_op_speedup)` — an op engages an extra core only per `grain`
//!    FLOPs of work and never beats the memory-bandwidth/NUMA ceiling.
//!    This is why large batches favor fewer-bigger ranks (DP catches up at
//!    BS >= 512) and why 48-core sequential wastes most of the node.
//!
//! `hyparflow calibrate` (or `hyparflow sim --calibrate`, which feeds the
//! result straight into the run) re-anchors `core_rate` and `g0` from
//! native-kernel measurements on this host; platform profiles carry
//! scaled defaults.

use super::Platform;
use crate::graph::{ModelGraph, NodeId};

/// Default per-op dispatch overhead if calibration is absent (seconds).
pub const PRIM_DISPATCH_DEFAULT: f64 = 80e-6;

#[derive(Clone, Debug)]
pub struct CostModel {
    /// Sustained per-core FLOP rate (f32).
    pub core_rate: f64,
    /// Fixed per-op dispatch overhead, seconds.
    pub dispatch: f64,
    /// Additional per-op overhead per core the op's thread pool spans.
    pub dispatch_per_core: f64,
    /// FLOPs per additional core of intra-op scaling.
    pub grain: f64,
    /// Intra-op speedup ceiling.
    pub max_speedup: f64,
}

impl CostModel {
    pub fn for_platform(p: &Platform) -> CostModel {
        CostModel {
            core_rate: p.core_gflops * 1e9,
            dispatch: p.dispatch_secs,
            dispatch_per_core: p.dispatch_per_core_secs,
            grain: p.grain_flops,
            max_speedup: p.max_intra_op_speedup,
        }
    }

    /// Effective cores an op of `w` FLOPs can use out of `c`.
    pub fn ceff(&self, w: f64, c: f64) -> f64 {
        c.min(1.0 + w / self.grain).min(self.max_speedup).max(1.0)
    }

    /// Per-op overhead when the op's pool spans `c` cores.
    pub fn overhead(&self, c: f64) -> f64 {
        self.dispatch + self.dispatch_per_core * c
    }

    /// Time for one op of `w` FLOPs on `c` cores.
    pub fn op_time(&self, w: f64, c: f64) -> f64 {
        if w <= 0.0 {
            return 0.0; // free ops (Input/Flatten) execute natively
        }
        self.overhead(c) + w / (self.core_rate * self.ceff(w, c))
    }

    /// Forward time of one node for a microbatch of `mb` on `c` cores.
    pub fn node_fwd(&self, g: &ModelGraph, n: NodeId, mb: usize, c: f64) -> f64 {
        self.op_time(g.node_cost(n).flops * mb as f64, c)
    }

    /// Backward is ~2x forward FLOPs (dgrad + wgrad) with its own dispatch.
    pub fn node_bwd(&self, g: &ModelGraph, n: NodeId, mb: usize, c: f64) -> f64 {
        let w = g.node_cost(n).flops * mb as f64;
        if w <= 0.0 {
            return 0.0;
        }
        self.op_time(2.0 * w, c)
    }

    /// ZB-H1 split backward, input-gradient half: ~1x forward FLOPs with
    /// its own dispatch. Splitting is not free —
    /// `node_bwd_input + node_bwd_weight > node_bwd` by one extra
    /// dispatch, which is the realistic price of zero-bubble scheduling.
    pub fn node_bwd_input(&self, g: &ModelGraph, n: NodeId, mb: usize, c: f64) -> f64 {
        self.op_time(g.node_cost(n).flops * mb as f64, c)
    }

    /// ZB-H1 split backward, weight-gradient half: ~1x forward FLOPs.
    pub fn node_bwd_weight(&self, g: &ModelGraph, n: NodeId, mb: usize, c: f64) -> f64 {
        self.op_time(g.node_cost(n).flops * mb as f64, c)
    }

    /// The calibration table as `key value` text (the format
    /// `hyparflow calibrate` writes and [`Self::apply_calibration`] reads).
    pub fn to_text(&self) -> String {
        format!(
            "core_rate {:.17e}\ndispatch {:.17e}\ndispatch_per_core {:.17e}\n\
             grain {:.17e}\nmax_speedup {:.17e}\n",
            self.core_rate, self.dispatch, self.dispatch_per_core, self.grain, self.max_speedup
        )
    }

    /// The calibration table as a flat JSON object (for `--calib-out
    /// x.json`); [`Self::apply_calibration`] sniffs and reads it back.
    pub fn to_json(&self) -> String {
        crate::util::JsonObj::new()
            .num("core_rate", self.core_rate)
            .num("dispatch", self.dispatch)
            .num("dispatch_per_core", self.dispatch_per_core)
            .num("grain", self.grain)
            .num("max_speedup", self.max_speedup)
            .build()
    }

    /// Load calibration overrides (written by `hyparflow calibrate` or
    /// `sim --calibrate --calib-out`). Two formats, sniffed by the leading
    /// character: `key value` text lines, or the flat JSON object
    /// [`Self::to_json`] emits. Unknown keys are hard errors either way.
    pub fn apply_calibration(&mut self, text: &str) -> anyhow::Result<()> {
        let apply = |cm: &mut CostModel, k: &str, v: f64| -> anyhow::Result<()> {
            match k {
                "core_rate" => cm.core_rate = v,
                "dispatch" => cm.dispatch = v,
                "dispatch_per_core" => cm.dispatch_per_core = v,
                "grain" => cm.grain = v,
                "max_speedup" => cm.max_speedup = v,
                other => anyhow::bail!("unknown calibration key '{other}'"),
            }
            Ok(())
        };
        let trimmed = text.trim();
        if trimmed.starts_with('{') {
            // Flat JSON object: {"key":num,...} — no nesting, no arrays.
            let body = trimmed
                .strip_prefix('{')
                .and_then(|s| s.strip_suffix('}'))
                .ok_or_else(|| anyhow::anyhow!("malformed calibration JSON"))?;
            for field in body.split(',').filter(|f| !f.trim().is_empty()) {
                let (k, v) = field
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("bad calibration field '{field}'"))?;
                let k = k.trim().trim_matches('"');
                apply(self, k, v.trim().parse::<f64>()?)?;
            }
            return Ok(());
        }
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (k, v) = (
                it.next().ok_or_else(|| anyhow::anyhow!("bad line '{line}'"))?,
                it.next()
                    .ok_or_else(|| anyhow::anyhow!("bad line '{line}'"))?
                    .parse::<f64>()?,
            );
            apply(self, k, v)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    fn cm() -> CostModel {
        CostModel::for_platform(&Platform::skylake48())
    }

    #[test]
    fn tiny_ops_are_dispatch_bound() {
        let c = cm();
        let t = c.op_time(1e3, 48.0);
        assert!((t - c.overhead(48.0)) / t < 0.01, "tiny op ~ dispatch: {t}");
    }

    #[test]
    fn per_op_overhead_grows_with_pool_width() {
        // The TF thread-pool pathology the paper exploits: a 48-core op
        // pays more fork/join than a 6-core op.
        let c = cm();
        assert!(c.overhead(48.0) > 2.0 * c.overhead(6.0));
    }

    #[test]
    fn big_ops_scale_until_ceiling() {
        let c = cm();
        let t1 = c.op_time(1e9, 1.0);
        let t8 = c.op_time(1e9, 8.0);
        let t48 = c.op_time(1e9, 48.0);
        assert!(t8 < t1 / 4.0, "8 cores should speed up big ops");
        // Ceiling: 48 cores no better than max_speedup (+ pool overhead).
        let floor = c.overhead(48.0) + 1e9 / (c.core_rate * c.max_speedup);
        assert!((t48 - floor).abs() / floor < 0.01, "{t48} vs {floor}");
    }

    #[test]
    fn ceff_monotone_in_work() {
        let c = cm();
        assert!(c.ceff(1e5, 48.0) < c.ceff(1e8, 48.0));
        assert!(c.ceff(1e8, 4.0) <= 4.0);
    }

    #[test]
    fn node_costs_positive_for_compute_nodes() {
        let g = zoo::resnet20_v1();
        let c = cm();
        for n in 0..g.num_nodes() {
            let f = c.node_fwd(&g, n, 8, 4.0);
            let b = c.node_bwd(&g, n, 8, 4.0);
            assert!(f >= 0.0 && b >= f, "node {n}: fwd {f} bwd {b}");
        }
    }

    #[test]
    fn calibration_overrides() {
        let mut c = cm();
        c.apply_calibration("# comment\ncore_rate 5e9\ndispatch 1e-4\n").unwrap();
        assert_eq!(c.core_rate, 5e9);
        assert_eq!(c.dispatch, 1e-4);
        assert!(c.apply_calibration("bogus 1").is_err());
    }

    #[test]
    fn split_backward_costs_more_than_fused() {
        // Two dispatches instead of one: the zero-bubble price.
        let g = zoo::resnet20_v1();
        let c = cm();
        for n in 0..g.num_nodes() {
            let fused = c.node_bwd(&g, n, 8, 4.0);
            let split = c.node_bwd_input(&g, n, 8, 4.0) + c.node_bwd_weight(&g, n, 8, 4.0);
            if fused > 0.0 {
                assert!(split > fused, "node {n}: split {split} !> fused {fused}");
            }
        }
    }

    #[test]
    fn calibration_round_trips_through_text_and_json() {
        let mut c = cm();
        c.core_rate = 5.4321e9;
        c.dispatch = 7.77e-5;
        c.dispatch_per_core = 1.23e-6;
        c.grain = 2.5e6;
        c.max_speedup = 11.5;
        for serialized in [c.to_text(), c.to_json()] {
            let mut d = cm();
            d.apply_calibration(&serialized).unwrap();
            assert_eq!(d.core_rate, c.core_rate, "{serialized}");
            assert_eq!(d.dispatch, c.dispatch);
            assert_eq!(d.dispatch_per_core, c.dispatch_per_core);
            assert_eq!(d.grain, c.grain);
            assert_eq!(d.max_speedup, c.max_speedup);
        }
        assert!(cm().apply_calibration("{\"bogus\":1}").is_err());
    }
}
