//! Calibrated cluster simulator (DESIGN.md substitution #3).
//!
//! The paper's multi-node results (Fig 7-13) were measured on Stampede2
//! Skylake nodes (Omni-Path) and an AMD EPYC cluster (IB-EDR). Neither is
//! available, so scaling experiments run on this model:
//!
//! - **compute**: per-op time `t(W, c) = g + W / (rate * ceff(W, c))` where
//!   `W` is FLOPs, `c` the cores given to the op, `g` the framework
//!   dispatch overhead, and `ceff` a saturating parallel-efficiency curve
//!   (ops only scale to as many cores as their work grain supports) — the
//!   mechanism behind the paper's "sequential TF cannot use 48 cores for
//!   small batches" observation that makes MP win at small batch sizes.
//! - **communication**: alpha-beta links (latency + bytes/bandwidth),
//!   intra-node vs inter-node; ring allreduce across replicas, one
//!   concurrent allreduce per model-partition (paper §5.3), overlapped
//!   with the other partitions' compute.
//! - **schedule**: the exact per-rank instruction program the Trainer
//!   interprets (`crate::schedule::Program`, GPipe or 1F1B), replayed as a
//!   discrete-event simulation with boundary + skip-edge payloads from the
//!   real `Partitioning`.
//!
//! Constants are anchored by `hyparflow calibrate` (PJRT measurements on
//! this host, scaled to platform profiles); the *shapes* of the figures
//! come from the mechanisms above, not from curve fitting.

mod cost;
mod pipeline;

pub use cost::{CostModel, PRIM_DISPATCH_DEFAULT};
pub use pipeline::{
    simulate_program, simulate_program_traced, simulate_step, simulate_step_traced, SimBreakdown,
};

use crate::graph::ModelGraph;
use crate::partition::Partitioning;
use crate::schedule::{ScheduleKind, SendMode, SendSemantics};

/// Hardware profile for one cluster flavor.
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub cores_per_node: usize,
    /// Sustained per-core f32 GFLOP/s for conv/matmul-type work.
    pub core_gflops: f64,
    /// FLOPs of work needed to profitably engage one extra core
    /// (intra-op parallel grain).
    pub grain_flops: f64,
    /// Framework per-op dispatch overhead (fixed part), seconds.
    pub dispatch_secs: f64,
    /// Per-op thread-pool fork/join cost per core spanned, seconds.
    pub dispatch_per_core_secs: f64,
    /// Hard cap on intra-op scaling (NUMA/memory-bandwidth ceiling).
    pub max_intra_op_speedup: f64,
    /// Inter-node link (Omni-Path / IB-EDR class).
    pub net_latency: f64,
    pub net_bw: f64, // bytes/sec
    /// Intra-node (shared-memory) link.
    pub shm_latency: f64,
    pub shm_bw: f64,
    pub mem_gb: f64,
}

impl Platform {
    /// Stampede2 Skylake partition: dual-socket Xeon 8160, 48 cores,
    /// 192 GB, 100 Gb/s Omni-Path.
    pub fn skylake48() -> Platform {
        Platform {
            name: "skylake-48c",
            cores_per_node: 48,
            core_gflops: 18.0,
            grain_flops: 6.0e6,
            dispatch_secs: 80e-6,
            dispatch_per_core_secs: 8e-6,
            max_intra_op_speedup: 16.0,
            net_latency: 1.8e-6,
            net_bw: 12.0e9,
            shm_latency: 0.6e-6,
            shm_bw: 24.0e9,
            mem_gb: 192.0,
        }
    }

    /// The paper's AMD platform: dual-socket EPYC 7551, 64 cores, IB-EDR.
    /// OpenBLAS on Zen1 sustains notably lower per-core conv throughput and
    /// the 4-die NUMA topology caps intra-op scaling harder — this is what
    /// produced the paper's 3.2x MP-over-sequential result (Fig 9).
    pub fn epyc64() -> Platform {
        Platform {
            name: "epyc-64c",
            cores_per_node: 64,
            core_gflops: 9.0,
            grain_flops: 8.0e6,
            dispatch_secs: 100e-6,
            // OpenBLAS pthread pool + 4-die NUMA: wider per-core fork/join
            // cost and a lower scaling ceiling than MKL-on-Skylake.
            dispatch_per_core_secs: 14e-6,
            max_intra_op_speedup: 8.0,
            net_latency: 1.5e-6,
            net_bw: 12.0e9,
            shm_latency: 0.7e-6,
            shm_bw: 20.0e9,
            mem_gb: 256.0,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Platform> {
        Ok(match name {
            "skylake" | "skylake48" | "skylake-48c" => Self::skylake48(),
            "epyc" | "epyc64" | "epyc-64c" => Self::epyc64(),
            _ => anyhow::bail!("unknown platform '{name}' (skylake|epyc)"),
        })
    }

    /// Point-to-point transfer time over the chosen link.
    pub fn p2p(&self, bytes: f64, inter_node: bool) -> f64 {
        if inter_node {
            self.net_latency + bytes / self.net_bw
        } else {
            self.shm_latency + bytes / self.shm_bw
        }
    }

    /// Ring allreduce across `r` ranks. `inter` selects the bottleneck
    /// link class.
    pub fn allreduce(&self, bytes: f64, r: usize, inter_node: bool) -> f64 {
        if r <= 1 {
            return 0.0;
        }
        let (lat, bw) = if inter_node {
            (self.net_latency, self.net_bw)
        } else {
            (self.shm_latency, self.shm_bw)
        };
        // MPI software overhead per message hop dominates tiny latencies.
        let hop = lat + 15e-6;
        2.0 * (r as f64 - 1.0) * (hop + (bytes / r as f64) / bw)
    }
}

/// One simulated scenario.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub platform: Platform,
    pub nodes: usize,
    /// Ranks (processes) per node.
    pub ppn: usize,
    pub partitions: usize,
    pub replicas: usize,
    /// Microbatch size per pipeline slot.
    pub microbatch: usize,
    /// Microbatches per step; per-replica batch = microbatch*num_mb.
    pub num_microbatches: usize,
    /// Overlap the per-partition allreduce with other partitions' compute
    /// (the paper's design). Off = single global allreduce after backward
    /// (plain Horovod DP behavior).
    pub overlap_allreduce: bool,
    /// Pipeline schedule to compile and replay (same IR the Trainer runs).
    pub schedule: ScheduleKind,
    /// Send ops to compile: blocking `Send*` or eager `PostSend*`/`WaitSend`
    /// pairs (MPI_Isend/MPI_Wait).
    pub send_mode: SendMode,
    /// Transport the DES models, mirroring the live fabric's
    /// [`crate::hfmpi::Transport`]. `Buffered` (hfmpi's default) has
    /// sends never block and posts complete at the wire; `Rendezvous`
    /// models synchronous MPI sends, where a blocking send parks the
    /// sender until the facing receive arrives and an eager post's
    /// `WaitSend` parks until the receive completes.
    pub transport: SendSemantics,
    pub cost: CostModel,
}

impl SimConfig {
    pub fn new(platform: Platform, partitions: usize, replicas: usize) -> SimConfig {
        let cost = CostModel::for_platform(&platform);
        SimConfig {
            platform,
            nodes: 1,
            ppn: partitions * replicas,
            partitions,
            replicas,
            microbatch: 8,
            num_microbatches: 4,
            overlap_allreduce: true,
            schedule: ScheduleKind::default(),
            send_mode: SendMode::Blocking,
            transport: SendSemantics::Buffered,
            cost,
        }
    }

    /// Total ranks.
    pub fn world(&self) -> usize {
        self.partitions * self.replicas
    }

    /// Cores available to each rank.
    pub fn cores_per_rank(&self) -> f64 {
        let slots = (self.nodes * self.ppn).max(1);
        debug_assert!(self.world() <= slots, "world {} > slots {slots}", self.world());
        (self.platform.cores_per_node as f64) / self.ppn as f64
    }

    /// Node index hosting a given (replica, partition) rank,
    /// replica-major placement (a replica's partitions stay close).
    pub fn node_of(&self, replica: usize, partition: usize) -> usize {
        let rank = replica * self.partitions + partition;
        rank / self.ppn
    }

    pub fn batch_per_replica(&self) -> usize {
        self.microbatch * self.num_microbatches
    }

    pub fn effective_batch(&self) -> usize {
        self.batch_per_replica() * self.replicas
    }
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub step_secs: f64,
    pub img_per_sec: f64,
    pub breakdown: SimBreakdown,
}

/// Simulate one synchronous training step of `g` under `cfg`.
pub fn simulate(g: &ModelGraph, pt: &Partitioning, cfg: &SimConfig) -> SimResult {
    let b = simulate_step(g, pt, cfg);
    let step = b.step_secs;
    SimResult {
        step_secs: step,
        img_per_sec: cfg.effective_batch() as f64 / step,
        breakdown: b,
    }
}

/// Simulate one step and also return the DES-clock hftrace — the same
/// event schema the instrumented engine records (`crate::trace`), so the
/// timeline can be exported to Chrome JSON or compared against a measured
/// run (`sim --trace out.json` / the cross-validation tests).
pub fn simulate_traced(
    g: &ModelGraph,
    pt: &Partitioning,
    cfg: &SimConfig,
) -> (SimResult, crate::trace::Trace) {
    let (b, trace) = simulate_step_traced(g, pt, cfg);
    let step = b.step_secs;
    (
        SimResult {
            step_secs: step,
            img_per_sec: cfg.effective_batch() as f64 / step,
            breakdown: b,
        },
        trace,
    )
}

/// Convenience: simulate the sequential baseline (1 rank, all cores,
/// single "microbatch" equal to the full batch).
pub fn simulate_sequential(g: &ModelGraph, platform: &Platform, batch: usize) -> SimResult {
    let pt = Partitioning::auto(g, 1).expect("P=1");
    let mut cfg = SimConfig::new(platform.clone(), 1, 1);
    cfg.nodes = 1;
    cfg.ppn = 1;
    cfg.microbatch = batch;
    cfg.num_microbatches = 1;
    simulate(g, &pt, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn p2p_and_allreduce_monotone() {
        let p = Platform::skylake48();
        assert!(p.p2p(1e6, true) > p.p2p(1e6, false));
        assert!(p.allreduce(1e8, 4, true) > p.allreduce(1e6, 4, true));
        assert!(p.allreduce(1e6, 8, true) > p.allreduce(1e6, 2, true));
        assert_eq!(p.allreduce(1e6, 1, true), 0.0);
    }

    #[test]
    fn sequential_throughput_scales_with_batch() {
        let g = zoo::resnet110_v1();
        let p = Platform::skylake48();
        let small = simulate_sequential(&g, &p, 8).img_per_sec;
        let large = simulate_sequential(&g, &p, 512).img_per_sec;
        assert!(
            large > 2.0 * small,
            "dispatch overhead should cap small-batch throughput: {small:.1} vs {large:.1}"
        );
    }

    #[test]
    fn mp_beats_sequential() {
        // The paper's core single-node claim (Figs 8/9): model parallelism
        // with per-sample pipelining beats sequential TF (which pays the
        // 48-core thread-pool toll on every op and caps at the node's
        // intra-op scaling ceiling).
        let g = zoo::resnet110_v1();
        let p = Platform::skylake48();
        let seq = simulate_sequential(&g, &p, 128);
        let pt = Partitioning::auto(&g, 48).unwrap();
        let mut cfg = SimConfig::new(p, 48, 1);
        cfg.ppn = 48;
        cfg.microbatch = 1;
        cfg.num_microbatches = 128;
        let mp = simulate(&g, &pt, &cfg);
        assert!(
            mp.img_per_sec > 1.5 * seq.img_per_sec,
            "MP {:.1} vs seq {:.1} img/s",
            mp.img_per_sec,
            seq.img_per_sec
        );
    }

    #[test]
    fn dp_allreduce_hurts_param_heavy_models() {
        // ResNet-1001 (30M params) must scale worse under DP than
        // ResNet-110 (1.7M) — the paper's Fig 10/12 observation.
        let p = Platform::skylake48();
        let rel_overhead = |g: &ModelGraph| {
            let pt = Partitioning::auto(g, 1).unwrap();
            let mut cfg = SimConfig::new(p.clone(), 1, 8);
            cfg.nodes = 8;
            cfg.ppn = 1;
            cfg.microbatch = 32;
            cfg.num_microbatches = 1;
            cfg.overlap_allreduce = false;
            let r = simulate(g, &pt, &cfg);
            r.breakdown.allreduce_secs / r.step_secs
        };
        let small = rel_overhead(&zoo::resnet110_v1());
        let big = rel_overhead(&zoo::resnet_v2(164, &[3, 32, 32], 10));
        // 164-v2 has ~2x the params of 110-v1 but also more compute; use
        // 1001 for the real contrast (kept cheap here).
        let huge = rel_overhead(&zoo::resnet1001_v2());
        assert!(huge > small, "allreduce share: 110={small:.3} 1001={huge:.3}");
        let _ = big;
    }

    #[test]
    fn epyc_sequential_is_slower_than_skylake() {
        let g = zoo::resnet110_v1();
        let sky = simulate_sequential(&g, &Platform::skylake48(), 256).img_per_sec;
        let amd = simulate_sequential(&g, &Platform::epyc64(), 256).img_per_sec;
        assert!(amd < sky, "epyc {amd:.1} should be slower than skylake {sky:.1}");
    }

    #[test]
    fn multi_node_mp_pays_network_latency() {
        let g = zoo::resnet110_v1();
        let p = Platform::skylake48();
        let pt = Partitioning::auto(&g, 16).unwrap();
        let mut one = SimConfig::new(p.clone(), 16, 1);
        one.nodes = 1;
        one.ppn = 16;
        let mut two = SimConfig::new(p, 16, 1);
        two.nodes = 2;
        two.ppn = 8;
        let t1 = simulate(&g, &pt, &one);
        let t2 = simulate(&g, &pt, &two);
        assert!(
            t2.breakdown.p2p_secs > t1.breakdown.p2p_secs,
            "cross-node boundaries cost more: {:.4} vs {:.4}",
            t2.breakdown.p2p_secs,
            t1.breakdown.p2p_secs
        );
    }
}
