//! Replay of the Trainer's fill/drain microbatch schedule on the cost
//! model: per-partition forward/backward stage times, boundary (and skip)
//! edge transfers on alpha-beta links, and the per-partition gradient
//! allreduce across replicas — overlapped with other partitions' compute
//! when `overlap_allreduce` is set (the paper's §5.3 design).

use super::{SimConfig};
use crate::graph::ModelGraph;
use crate::partition::Partitioning;

/// Where the simulated step time went.
#[derive(Clone, Debug, Default)]
pub struct SimBreakdown {
    pub step_secs: f64,
    /// Bottleneck partition's pure compute (fwd+bwd, all microbatches).
    pub compute_secs: f64,
    /// Total boundary/skip wire time (all edges, all microbatches).
    pub p2p_secs: f64,
    /// Slowest partition's gradient allreduce.
    pub allreduce_secs: f64,
    /// step - compute of the bottleneck stage = pipeline bubble + comm
    /// exposed on the critical path.
    pub bubble_secs: f64,
    /// Peak per-rank memory estimate, bytes (for trainability gating).
    pub mem_bytes: u64,
}

/// Simulate one synchronous step; returns the time breakdown.
pub fn simulate_step(g: &ModelGraph, pt: &Partitioning, cfg: &SimConfig) -> SimBreakdown {
    let p = pt.num_partitions;
    let m = cfg.num_microbatches.max(1);
    let cores = cfg.cores_per_rank();
    // Memory bandwidth is a node-shared resource: concurrent ranks split
    // the node's intra-op scaling ceiling in proportion to their core
    // share (floor 1 — single-core ranks work out of cache and dodge the
    // DRAM ceiling).
    let mut cm = cfg.cost.clone();
    let share = cores / cfg.platform.cores_per_node as f64;
    cm.max_speedup = (cm.max_speedup * share).max(1.0);
    let cm = &cm;

    // Per-partition stage times for one microbatch.
    let f: Vec<f64> = (0..p)
        .map(|i| {
            pt.parts[i]
                .iter()
                .map(|&n| cm.node_fwd(g, n, cfg.microbatch, cores))
                .sum()
        })
        .collect();
    let b: Vec<f64> = (0..p)
        .map(|i| {
            pt.parts[i]
                .iter()
                .map(|&n| cm.node_bwd(g, n, cfg.microbatch, cores))
                .sum()
        })
        .collect();

    // Edge transfer times (per microbatch), grouped by consumer partition.
    // Placement decides intra- vs inter-node (replica 0 is representative:
    // all replicas are placed identically modulo node offset).
    let edge_time = |src_part: usize, dst_part: usize, bytes: f64| -> f64 {
        let inter = cfg.node_of(0, src_part) != cfg.node_of(0, dst_part);
        cfg.platform.p2p(bytes, inter)
    };
    // in_comm[i] = per-mb inbound transfer time to partition i (forward);
    // the same edges reversed carry errors backward.
    let mut in_comm = vec![0.0f64; p];
    let mut out_comm = vec![0.0f64; p];
    let mut total_wire = 0.0;
    for e in &pt.edges {
        let bytes =
            (g.nodes[e.src_node].out_shape.iter().product::<usize>() * 4 * cfg.microbatch) as f64;
        let t = edge_time(e.src_part, e.dst_part, bytes);
        in_comm[e.dst_part] += t;
        out_comm[e.src_part] += t;
        total_wire += t;
    }

    // ---- forward fill ----
    // fwd_end[i][k]: partition i finishes microbatch k's forward.
    let mut fwd_end = vec![vec![0.0f64; m]; p];
    for k in 0..m {
        for i in 0..p {
            let stage_free = if k > 0 { fwd_end[i][k - 1] } else { 0.0 };
            // Upstream dependencies: any partition j<i feeding i must have
            // finished microbatch k and shipped the boundary tensors.
            let mut dep: f64 = 0.0;
            for e in pt.recvs_of(i) {
                let bytes = (g.nodes[e.src_node].out_shape.iter().product::<usize>()
                    * 4
                    * cfg.microbatch) as f64;
                let t = edge_time(e.src_part, e.dst_part, bytes);
                dep = dep.max(fwd_end[e.src_part][k] + t);
            }
            let start = stage_free.max(dep);
            fwd_end[i][k] = start + f[i];
        }
    }

    // ---- backward drain (microbatches in reverse, after local fwd) ----
    let mut bwd_end = vec![vec![0.0f64; m]; p];
    for (ki, k) in (0..m).rev().enumerate() {
        for i in (0..p).rev() {
            let stage_free = if ki > 0 {
                bwd_end[i][k + 1] // previous processed microbatch (k+1)
            } else {
                fwd_end[i][m - 1] // engine finishes all fwd before bwd
            };
            let mut dep: f64 = 0.0;
            for e in pt.sends_of(i) {
                // Error for edge (i -> d) comes back from d.
                let bytes = (g.nodes[e.src_node].out_shape.iter().product::<usize>()
                    * 4
                    * cfg.microbatch) as f64;
                let t = edge_time(e.dst_part, e.src_part, bytes);
                dep = dep.max(bwd_end[e.dst_part][k] + t);
            }
            let start = stage_free.max(dep);
            bwd_end[i][k] = start + b[i];
        }
    }

    // ---- gradient allreduce across replicas ----
    // One communicator per partition (paper §5.3); replicas of partition i
    // sit ppn apart, so they span nodes whenever a replica doesn't fit in
    // one node times... placement check: node_of(r, i) varies with r.
    let mut ar = vec![0.0f64; p];
    if cfg.replicas > 1 {
        for i in 0..p {
            let inter = (0..cfg.replicas)
                .map(|r| cfg.node_of(r, i))
                .collect::<std::collections::BTreeSet<_>>()
                .len()
                > 1;
            let bytes = (pt.params_of(g, i) * 4) as f64;
            ar[i] = cfg.platform.allreduce(bytes, cfg.replicas, inter);
        }
    }

    let global_bwd_end = (0..p).map(|i| bwd_end[i][0]).fold(0.0, f64::max);
    let step = if cfg.overlap_allreduce {
        // Each partition launches its allreduce as soon as its own backward
        // drains — overlapping with slower partitions' compute.
        (0..p).map(|i| bwd_end[i][0] + ar[i]).fold(0.0, f64::max)
    } else {
        // Plain DP: single fused allreduce of the whole model after the
        // global backward.
        let total_bytes: f64 = (0..p).map(|i| (pt.params_of(g, i) * 4) as f64).sum();
        let inter = cfg.nodes > 1;
        global_bwd_end + cfg.platform.allreduce(total_bytes, cfg.replicas, inter)
    };

    let bottleneck_compute = (0..p)
        .map(|i| (f[i] + b[i]) * m as f64)
        .fold(0.0, f64::max);
    let mem = (0..p)
        .map(|i| {
            crate::mem::partition_memory(g, pt, i, cfg.microbatch, m).total()
        })
        .max()
        .unwrap_or(0);

    SimBreakdown {
        step_secs: step,
        compute_secs: bottleneck_compute,
        p2p_secs: total_wire * m as f64,
        allreduce_secs: ar.iter().cloned().fold(0.0, f64::max),
        bubble_secs: (step - bottleneck_compute).max(0.0),
        mem_bytes: mem,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::sim::Platform;

    fn base(parts: usize, m: usize) -> (ModelGraph, Partitioning, SimConfig) {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, parts).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), parts, 1);
        cfg.ppn = parts;
        cfg.num_microbatches = m;
        (g, pt, cfg)
    }

    #[test]
    fn pipeline_fills_and_drains() {
        let (g, pt, cfg) = base(4, 8);
        let r = simulate_step(&g, &pt, &cfg);
        // Step >= bottleneck compute (bubbles + comm only add).
        assert!(r.step_secs >= r.compute_secs);
        assert!(r.bubble_secs >= 0.0);
    }

    #[test]
    fn more_microbatches_amortize_the_bubble() {
        let (g, pt, mut cfg) = base(4, 2);
        let r2 = simulate_step(&g, &pt, &cfg);
        cfg.num_microbatches = 16;
        let r16 = simulate_step(&g, &pt, &cfg);
        // Throughput per sample improves with pipeline depth.
        let t2 = r2.step_secs / (2.0 * cfg.microbatch as f64);
        let t16 = r16.step_secs / (16.0 * cfg.microbatch as f64);
        assert!(t16 < t2, "per-sample time {t16} !< {t2}");
    }

    #[test]
    fn single_partition_has_no_bubble_or_wire() {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, 1).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 1, 1);
        cfg.ppn = 1;
        cfg.num_microbatches = 1;
        let r = simulate_step(&g, &pt, &cfg);
        assert_eq!(r.p2p_secs, 0.0);
        assert!(r.bubble_secs < 1e-12);
        assert_eq!(r.allreduce_secs, 0.0);
    }

    #[test]
    fn overlap_beats_unfused_allreduce() {
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 4);
        cfg.nodes = 4;
        cfg.ppn = 4;
        cfg.num_microbatches = 8;
        cfg.overlap_allreduce = true;
        let o = simulate_step(&g, &pt, &cfg);
        cfg.overlap_allreduce = false;
        let n = simulate_step(&g, &pt, &cfg);
        assert!(
            o.step_secs <= n.step_secs,
            "overlapped {:.4} should not exceed unoverlapped {:.4}",
            o.step_secs,
            n.step_secs
        );
    }

    #[test]
    fn memory_gate_reported() {
        let (g, pt, cfg) = base(2, 4);
        let r = simulate_step(&g, &pt, &cfg);
        assert!(r.mem_bytes > 0);
    }
}
