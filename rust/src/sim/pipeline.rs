//! Replay of the **schedule IR** on the cost model: the simulator
//! interprets the exact per-rank [`Program`](crate::schedule::Program) the
//! Trainer executes — same instruction streams, same message linearization
//! — as a discrete-event simulation: compute ops advance a rank's clock by
//! the cost-model time, sends publish message-availability times over
//! alpha-beta links, receives wait for them. The transport is selectable
//! ([`SimConfig::transport`]): `Buffered` matches the hfmpi fabric (sends
//! never block), `Rendezvous` models synchronous MPI sends where a
//! transfer starts only when both sides are ready — under which blocking
//! 1F1B-family programs deadlock and the eager `PostSend*`/`WaitSend`
//! programs do not. The per-partition gradient allreduce across replicas is applied
//! at the program's `AllreduceGrads` op — overlapped with other
//! partitions' compute when `overlap_allreduce` is set (the paper's §5.3
//! design).
//!
//! Because simulation and execution share one schedule object, a simulated
//! bubble is by construction a property of the program the engine runs,
//! under either generator (GPipe fill/drain or 1F1B); peak memory comes
//! from the same program's stash live intervals (`crate::mem`).

use super::SimConfig;
use crate::graph::ModelGraph;
use crate::partition::Partitioning;
use crate::schedule::{Instr, Program, SendSemantics};
use crate::trace::{RankTrace, Trace};
use std::collections::HashMap;

/// Where the simulated step time went.
#[derive(Clone, Debug, Default)]
pub struct SimBreakdown {
    pub step_secs: f64,
    /// Bottleneck partition's pure compute (fwd+bwd, all microbatches).
    pub compute_secs: f64,
    /// Total boundary/skip wire time (all edges, all microbatches).
    pub p2p_secs: f64,
    /// Slowest partition's gradient allreduce.
    pub allreduce_secs: f64,
    /// step - compute of the bottleneck stage = pipeline bubble + comm
    /// exposed on the critical path.
    pub bubble_secs: f64,
    /// Peak per-rank memory estimate, bytes (for trainability gating),
    /// derived from the schedule program's stash live intervals.
    pub mem_bytes: u64,
}

/// Simulate one synchronous step of `program`; returns the time breakdown.
pub fn simulate_program(
    g: &ModelGraph,
    pt: &Partitioning,
    cfg: &SimConfig,
    program: &Program,
) -> SimBreakdown {
    sim_impl(g, pt, cfg, program, None)
}

/// Like [`simulate_program`], but also emits an hftrace timeline from the
/// DES clock — the same event schema the instrumented engine records
/// (built by `crate::trace::instr_event` on both sides), so simulated and
/// measured traces feed the same exporters and reports.
pub fn simulate_program_traced(
    g: &ModelGraph,
    pt: &Partitioning,
    cfg: &SimConfig,
    program: &Program,
) -> (SimBreakdown, Trace) {
    let mut trace =
        Trace { ranks: (0..program.num_partitions).map(RankTrace::new).collect() };
    let b = sim_impl(g, pt, cfg, program, Some(&mut trace));
    (b, trace)
}

fn sim_impl(
    g: &ModelGraph,
    pt: &Partitioning,
    cfg: &SimConfig,
    program: &Program,
    mut trace: Option<&mut Trace>,
) -> SimBreakdown {
    // Ranks (processes), not stages: under interleaved schedules the
    // partitioning is stage-level (`program.num_stages` chunks) while the
    // DES replays one clock per rank.
    let p = program.num_partitions;
    let m = program.num_microbatches;
    let cores = cfg.cores_per_rank();
    // Memory bandwidth is a node-shared resource: concurrent ranks split
    // the node's intra-op scaling ceiling in proportion to their core
    // share (floor 1 — single-core ranks work out of cache and dodge the
    // DRAM ceiling).
    let mut cm = cfg.cost.clone();
    let share = cores / cfg.platform.cores_per_node as f64;
    cm.max_speedup = (cm.max_speedup * share).max(1.0);
    let cm = &cm;

    // Edge transfer times (per microbatch). Placement decides intra- vs
    // inter-node (replica 0 is representative: all replicas are placed
    // identically modulo node offset).
    let edge_secs: Vec<f64> = pt
        .edges
        .iter()
        .map(|e| {
            let bytes = (g.nodes[e.src_node].out_shape.iter().product::<usize>()
                * 4
                * cfg.microbatch) as f64;
            // Stage -> rank via the round-robin map before placement.
            let inter = cfg.node_of(0, e.src_part % p) != cfg.node_of(0, e.dst_part % p);
            cfg.platform.p2p(bytes, inter)
        })
        .collect();
    let total_wire: f64 = edge_secs.iter().sum();

    // ---- gradient allreduce across replicas ----
    // One communicator per partition (paper §5.3); inter-node when a
    // partition's replicas span nodes. Computed up front so the DES can
    // stamp `AllreduceGrads` trace spans with their modeled duration.
    let mut ar = vec![0.0f64; p];
    if cfg.replicas > 1 {
        for i in 0..p {
            let inter = (0..cfg.replicas)
                .map(|r| cfg.node_of(r, i))
                .collect::<std::collections::BTreeSet<_>>()
                .len()
                > 1;
            // A rank allreduces the parameters of all its stages.
            let bytes: f64 = program
                .stages_of(i)
                .iter()
                .map(|&s| (pt.params_of(g, s) * 4) as f64)
                .sum();
            ar[i] = cfg.platform.allreduce(bytes, cfg.replicas, inter);
        }
    }
    // Resident parameter bytes per rank (tags allreduce/opt trace spans;
    // same quantity the engine computes from its parameter tensors).
    let rank_param_bytes: Vec<u64> = (0..p)
        .map(|r| {
            program
                .stages_of(r)
                .iter()
                .map(|&s| (pt.params_of(g, s) * 4) as u64)
                .sum()
        })
        .collect();

    // ---- event-driven replay of the per-rank instruction streams ----
    // Under the `Buffered` transport (the hfmpi fabric), sends never block
    // the sender; the payload becomes available to the receiver after the
    // link time, and `WaitSend` is trivially complete. Under `Rendezvous`
    // (synchronous MPI sends), a transfer starts only when *both* sides
    // are ready: a blocking send parks the sender until the facing receive
    // arrives, an eager post returns immediately but its `WaitSend` parks
    // until the receive completes. Receives wait in both models.
    let rendezvous = matches!(cfg.transport, SendSemantics::Rendezvous);
    let handle_keys: Vec<HashMap<usize, (usize, usize, u8)>> =
        (0..p).map(|r| program.handle_keys(r)).collect();
    let mut pc = vec![0usize; p];
    let mut clock = vec![0.0f64; p];
    // (edge, mb, class 0=act 1=err) -> availability time at the receiver.
    let mut avail: HashMap<(usize, usize, u8), f64> = HashMap::new();
    // Rendezvous handshake state: the time each side became ready, and
    // when the receive completed (what `WaitSend` waits for).
    let mut send_ready: HashMap<(usize, usize, u8), f64> = HashMap::new();
    let mut recv_ready: HashMap<(usize, usize, u8), f64> = HashMap::new();
    let mut recv_done: HashMap<(usize, usize, u8), f64> = HashMap::new();
    loop {
        let mut progressed = false;
        let mut done = true;
        for r in 0..p {
            let prog = program.rank(r);
            while pc[r] < prog.len() {
                let instr = prog[pc[r]];
                // Blocked ops `break` without advancing the clock, so on
                // the attempt that finally succeeds this is still the time
                // the rank first reached the instruction — the span start.
                let t_in = clock[r];
                match instr {
                    Instr::FwdCompute { node, .. } => {
                        clock[r] += cm.node_fwd(g, node, cfg.microbatch, cores);
                    }
                    Instr::BwdCompute { node, .. } => {
                        clock[r] += cm.node_bwd(g, node, cfg.microbatch, cores);
                    }
                    Instr::BwdInput { node, .. } => {
                        clock[r] += cm.node_bwd_input(g, node, cfg.microbatch, cores);
                    }
                    Instr::BwdWeight { node, .. } => {
                        clock[r] += cm.node_bwd_weight(g, node, cfg.microbatch, cores);
                    }
                    Instr::SendActivation { edge, mb, .. } => {
                        let key = (edge, mb, 0);
                        if rendezvous {
                            // Publish readiness; block until the facing
                            // receive is posted, then ride the wire.
                            if !send_ready.contains_key(&key) {
                                send_ready.insert(key, clock[r]);
                                progressed = true;
                            }
                            let Some(&rr) = recv_ready.get(&key) else { break };
                            let end = send_ready[&key].max(rr) + edge_secs[edge];
                            clock[r] = clock[r].max(end);
                            avail.entry(key).or_insert(end);
                        } else {
                            avail.insert(key, clock[r] + edge_secs[edge]);
                        }
                    }
                    Instr::SendError { edge, mb, .. } => {
                        // Error payloads retrace the edge in reverse; same
                        // bytes, same link class.
                        let key = (edge, mb, 1);
                        if rendezvous {
                            if !send_ready.contains_key(&key) {
                                send_ready.insert(key, clock[r]);
                                progressed = true;
                            }
                            let Some(&rr) = recv_ready.get(&key) else { break };
                            let end = send_ready[&key].max(rr) + edge_secs[edge];
                            clock[r] = clock[r].max(end);
                            avail.entry(key).or_insert(end);
                        } else {
                            avail.insert(key, clock[r] + edge_secs[edge]);
                        }
                    }
                    Instr::PostSendActivation { edge, mb, .. } => {
                        // Nonblocking: publish and move on; the handshake
                        // (if rendezvous) completes at the receive.
                        if rendezvous {
                            send_ready.entry((edge, mb, 0)).or_insert(clock[r]);
                        } else {
                            avail.insert((edge, mb, 0), clock[r] + edge_secs[edge]);
                        }
                    }
                    Instr::PostSendError { edge, mb, .. } => {
                        if rendezvous {
                            send_ready.entry((edge, mb, 1)).or_insert(clock[r]);
                        } else {
                            avail.insert((edge, mb, 1), clock[r] + edge_secs[edge]);
                        }
                    }
                    Instr::WaitSend { handle } => {
                        if rendezvous {
                            let key = handle_keys[r][&handle];
                            let Some(&t) = recv_done.get(&key) else { break };
                            clock[r] = clock[r].max(t);
                        }
                        // Buffered: the fabric took the payload at post
                        // time; the wait is free.
                    }
                    Instr::RecvActivation { edge, mb, .. } => {
                        let key = (edge, mb, 0);
                        if rendezvous {
                            if !recv_ready.contains_key(&key) {
                                recv_ready.insert(key, clock[r]);
                                progressed = true;
                            }
                            if let Some(&sr) = send_ready.get(&key) {
                                let end = sr.max(recv_ready[&key]) + edge_secs[edge];
                                avail.entry(key).or_insert(end);
                            }
                        }
                        let Some(&t) = avail.get(&key) else { break };
                        clock[r] = clock[r].max(t);
                        recv_done.entry(key).or_insert(clock[r]);
                    }
                    Instr::RecvError { edge, mb, .. } => {
                        let key = (edge, mb, 1);
                        if rendezvous {
                            if !recv_ready.contains_key(&key) {
                                recv_ready.insert(key, clock[r]);
                                progressed = true;
                            }
                            if let Some(&sr) = send_ready.get(&key) {
                                let end = sr.max(recv_ready[&key]) + edge_secs[edge];
                                avail.entry(key).or_insert(end);
                            }
                        }
                        let Some(&t) = avail.get(&key) else { break };
                        clock[r] = clock[r].max(t);
                        recv_done.entry(key).or_insert(clock[r]);
                    }
                    Instr::DropStash { .. }
                    | Instr::AllreduceGrads
                    | Instr::OptStep => {}
                }
                if let Some(tr) = trace.as_deref_mut() {
                    let pbytes = rank_param_bytes[r];
                    let mut ev = crate::trace::instr_event(g, pt, cfg.microbatch, &instr, pbytes);
                    ev.t0 = t_in;
                    // The per-rank allreduce runs off the DES clock (it only
                    // shifts the final step time), so its span gets the
                    // modeled duration without advancing `clock`.
                    ev.t1 = if matches!(instr, Instr::AllreduceGrads) {
                        t_in + ar[r]
                    } else {
                        clock[r]
                    };
                    tr.ranks[r].push(ev);
                }
                pc[r] += 1;
                progressed = true;
            }
            if pc[r] < prog.len() {
                done = false;
            }
        }
        if done {
            break;
        }
        assert!(
            progressed,
            "schedule program stalled in simulation under {:?} transport — \
             the conformance checker should have caught this (blocking-send \
             programs deadlock on rendezvous links; compile with \
             SendMode::Eager)",
            cfg.transport
        );
    }

    let step = if cfg.overlap_allreduce {
        // Each partition launches its allreduce as soon as its own backward
        // drains — overlapping with slower partitions' compute.
        (0..p).map(|i| clock[i] + ar[i]).fold(0.0, f64::max)
    } else {
        // Plain DP: single fused allreduce of the whole model after the
        // global backward.
        let global_end = clock.iter().cloned().fold(0.0, f64::max);
        let total_bytes: f64 = (0..pt.num_partitions)
            .map(|s| (pt.params_of(g, s) * 4) as f64)
            .sum();
        let inter = cfg.nodes > 1;
        global_end + cfg.platform.allreduce(total_bytes, cfg.replicas, inter)
    };

    // Per-rank pure compute totals (for the bubble accounting), derived
    // from the program's own op counts. Counts aggregate per
    // (node, op-kind) and sum in sorted key order, so two schedules doing
    // the same work report bitwise-identical compute regardless of
    // instruction order (the GPipe-vs-1F1B tests assert exact equality).
    let bottleneck_compute = (0..p)
        .map(|r| {
            let mut counts: std::collections::BTreeMap<(usize, u8), usize> =
                std::collections::BTreeMap::new();
            for i in program.rank(r) {
                let key = match *i {
                    Instr::FwdCompute { node, .. } => Some((node, 0u8)),
                    Instr::BwdCompute { node, .. } => Some((node, 1)),
                    Instr::BwdInput { node, .. } => Some((node, 2)),
                    Instr::BwdWeight { node, .. } => Some((node, 3)),
                    _ => None,
                };
                if let Some(k) = key {
                    *counts.entry(k).or_insert(0) += 1;
                }
            }
            counts
                .iter()
                .map(|(&(n, kind), &c)| {
                    let t = match kind {
                        0 => cm.node_fwd(g, n, cfg.microbatch, cores),
                        1 => cm.node_bwd(g, n, cfg.microbatch, cores),
                        2 => cm.node_bwd_input(g, n, cfg.microbatch, cores),
                        _ => cm.node_bwd_weight(g, n, cfg.microbatch, cores),
                    };
                    t * c as f64
                })
                .sum::<f64>()
        })
        .fold(0.0, f64::max);

    let mem = (0..p)
        .map(|i| {
            crate::mem::partition_memory_scheduled(g, pt, i, cfg.microbatch, program).total()
        })
        .max()
        .unwrap_or(0);

    SimBreakdown {
        step_secs: step,
        compute_secs: bottleneck_compute,
        p2p_secs: total_wire * m as f64,
        allreduce_secs: ar.iter().cloned().fold(0.0, f64::max),
        bubble_secs: (step - bottleneck_compute).max(0.0),
        mem_bytes: mem,
    }
}

/// Compile the configured schedule and simulate one step.
pub fn simulate_step(g: &ModelGraph, pt: &Partitioning, cfg: &SimConfig) -> SimBreakdown {
    let program =
        Program::compile_with(g, pt, cfg.num_microbatches.max(1), cfg.schedule, cfg.send_mode);
    simulate_program(g, pt, cfg, &program)
}

/// Compile the configured schedule and simulate one step, returning the
/// DES-clock hftrace alongside the breakdown.
pub fn simulate_step_traced(
    g: &ModelGraph,
    pt: &Partitioning,
    cfg: &SimConfig,
) -> (SimBreakdown, Trace) {
    let program =
        Program::compile_with(g, pt, cfg.num_microbatches.max(1), cfg.schedule, cfg.send_mode);
    simulate_program_traced(g, pt, cfg, &program)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;
    use crate::schedule::ScheduleKind;
    use crate::sim::Platform;

    fn base(parts: usize, m: usize) -> (ModelGraph, Partitioning, SimConfig) {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, parts).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), parts, 1);
        cfg.ppn = parts;
        cfg.num_microbatches = m;
        (g, pt, cfg)
    }

    #[test]
    fn pipeline_fills_and_drains() {
        let (g, pt, cfg) = base(4, 8);
        let r = simulate_step(&g, &pt, &cfg);
        // Step >= bottleneck compute (bubbles + comm only add).
        assert!(r.step_secs >= r.compute_secs);
        assert!(r.bubble_secs >= 0.0);
    }

    #[test]
    fn more_microbatches_amortize_the_bubble() {
        let (g, pt, mut cfg) = base(4, 2);
        let r2 = simulate_step(&g, &pt, &cfg);
        cfg.num_microbatches = 16;
        let r16 = simulate_step(&g, &pt, &cfg);
        // Throughput per sample improves with pipeline depth.
        let t2 = r2.step_secs / (2.0 * cfg.microbatch as f64);
        let t16 = r16.step_secs / (16.0 * cfg.microbatch as f64);
        assert!(t16 < t2, "per-sample time {t16} !< {t2}");
    }

    #[test]
    fn single_partition_has_no_bubble_or_wire() {
        let g = zoo::resnet20_v1();
        let pt = Partitioning::auto(&g, 1).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 1, 1);
        cfg.ppn = 1;
        cfg.num_microbatches = 1;
        let r = simulate_step(&g, &pt, &cfg);
        assert_eq!(r.p2p_secs, 0.0);
        assert!(r.bubble_secs < 1e-12);
        assert_eq!(r.allreduce_secs, 0.0);
    }

    #[test]
    fn overlap_beats_unfused_allreduce() {
        let g = zoo::resnet56_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 4);
        cfg.nodes = 4;
        cfg.ppn = 4;
        cfg.num_microbatches = 8;
        cfg.overlap_allreduce = true;
        let o = simulate_step(&g, &pt, &cfg);
        cfg.overlap_allreduce = false;
        let n = simulate_step(&g, &pt, &cfg);
        assert!(
            o.step_secs <= n.step_secs,
            "overlapped {:.4} should not exceed unoverlapped {:.4}",
            o.step_secs,
            n.step_secs
        );
    }

    #[test]
    fn memory_gate_reported() {
        let (g, pt, cfg) = base(2, 4);
        let r = simulate_step(&g, &pt, &cfg);
        assert!(r.mem_bytes > 0);
    }

    #[test]
    fn one_f1b_cuts_peak_memory_at_deep_pipelines() {
        // The acceptance criterion of the schedule-IR refactor: with
        // num_microbatches > num_partitions, 1F1B's bounded in-flight
        // window gives strictly lower peak memory than GPipe, while both
        // replay the same per-microbatch compute.
        let (g, pt, mut cfg) = base(4, 16);
        cfg.schedule = ScheduleKind::GPipe;
        let gp = simulate_step(&g, &pt, &cfg);
        cfg.schedule = ScheduleKind::OneF1B;
        let f1b = simulate_step(&g, &pt, &cfg);
        assert!(
            f1b.mem_bytes < gp.mem_bytes,
            "1f1b peak {} must undercut gpipe {}",
            f1b.mem_bytes,
            gp.mem_bytes
        );
        assert_eq!(f1b.compute_secs, gp.compute_secs, "same work either way");
    }

    #[test]
    fn newer_schedules_cut_the_bubble_fraction() {
        // The ISSUE 7 acceptance criterion at m >= 2*depth: interleaved
        // 1F1B shrinks fill/drain to per-chunk units, ZB-H1 fills the
        // drain with deferred weight-grad work — both strictly below
        // 1F1B's bubble fraction.
        let g = zoo::resnet110_v1();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 1);
        cfg.ppn = 4;
        cfg.num_microbatches = 16;
        cfg.schedule = ScheduleKind::OneF1B;
        let pt_flat = Partitioning::auto(&g, 4).unwrap();
        let f1b = simulate_step(&g, &pt_flat, &cfg);
        let frac = |r: &SimBreakdown| r.bubble_secs / r.step_secs;
        cfg.schedule = ScheduleKind::ZbH1;
        let zb = simulate_step(&g, &pt_flat, &cfg);
        assert!(
            frac(&zb) < frac(&f1b),
            "zb_h1 bubble frac {:.4} !< 1f1b {:.4}",
            frac(&zb),
            frac(&f1b)
        );
        cfg.schedule = ScheduleKind::Interleaved1F1B { v: 2 };
        let pt_i = cfg.schedule.partitioning(&g, 4).unwrap();
        let il = simulate_step(&g, &pt_i, &cfg);
        assert!(
            frac(&il) < frac(&f1b),
            "interleaved bubble frac {:.4} !< 1f1b {:.4}",
            frac(&il),
            frac(&f1b)
        );
    }

    #[test]
    fn eager_sends_are_free_on_the_buffered_transport() {
        // Under the buffered fabric a post publishes at the same clock a
        // blocking send would and the wait is free — timing results are
        // identical, so every existing benchmark number survives the
        // eager-send rewrite.
        use crate::schedule::SendMode;
        for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B, ScheduleKind::ZbH1] {
            let (g, pt, mut cfg) = base(4, 8);
            cfg.schedule = kind;
            let blocking = simulate_step(&g, &pt, &cfg);
            cfg.send_mode = SendMode::Eager;
            let eager = simulate_step(&g, &pt, &cfg);
            assert_eq!(
                blocking.step_secs, eager.step_secs,
                "{kind:?}: eager sends must not change buffered timing"
            );
        }
    }

    #[test]
    fn eager_one_f1b_completes_on_a_rendezvous_link() {
        // The tentpole: 1F1B's facing blocking sends deadlock on
        // synchronous links, the eager rewrite does not. The DES asserts
        // on stall, so completing at all is the property under test; the
        // handshake can only delay transfers, never speed them up.
        use crate::schedule::{SendMode, SendSemantics};
        let (g, pt, mut cfg) = base(4, 8);
        cfg.schedule = ScheduleKind::OneF1B;
        let buffered = simulate_step(&g, &pt, &cfg);
        cfg.send_mode = SendMode::Eager;
        cfg.transport = SendSemantics::Rendezvous;
        let rdv = simulate_step(&g, &pt, &cfg);
        assert!(
            rdv.step_secs >= buffered.step_secs,
            "rendezvous handshakes cannot beat buffered sends: {:.6} vs {:.6}",
            rdv.step_secs,
            buffered.step_secs
        );
    }

    #[test]
    fn blocking_gpipe_completes_on_a_rendezvous_link() {
        // GPipe's §6.3 message linearization is rendezvous-safe even with
        // blocking sends — the forward wave never has facing sends.
        use crate::schedule::SendSemantics;
        let (g, pt, mut cfg) = base(4, 8);
        cfg.schedule = ScheduleKind::GPipe;
        cfg.transport = SendSemantics::Rendezvous;
        let r = simulate_step(&g, &pt, &cfg);
        assert!(r.step_secs >= r.compute_secs);
    }

    #[test]
    #[should_panic(expected = "stalled in simulation")]
    fn blocking_one_f1b_deadlocks_on_a_rendezvous_link() {
        // The regression canary at the simulator layer: the pre-eager
        // 1F1B program really does deadlock on a synchronous transport.
        use crate::schedule::SendSemantics;
        let (g, pt, mut cfg) = base(4, 8);
        cfg.schedule = ScheduleKind::OneF1B;
        cfg.transport = SendSemantics::Rendezvous;
        simulate_step(&g, &pt, &cfg);
    }

    #[test]
    fn traced_simulation_matches_untraced_and_covers_every_instr() {
        use crate::schedule::SendMode;
        let (g, pt, mut cfg) = base(4, 8);
        cfg.schedule = ScheduleKind::OneF1B;
        cfg.send_mode = SendMode::Eager;
        let plain = simulate_step(&g, &pt, &cfg);
        let (traced, tr) = simulate_step_traced(&g, &pt, &cfg);
        assert_eq!(plain.step_secs, traced.step_secs, "tracing is observation-only");
        // Every instruction of every rank became exactly one span, in
        // program order with a consistent DES clock.
        let program = Program::compile_with(&g, &pt, 8, cfg.schedule, cfg.send_mode);
        assert_eq!(tr.ranks.len(), 4);
        for (r, rank) in tr.ranks.iter().enumerate() {
            assert_eq!(rank.events.len(), program.rank(r).len());
            for w in rank.events.windows(2) {
                assert!(w[1].t0 >= w[0].t0, "rank {r}: span starts out of order");
            }
            for ev in &rank.events {
                assert!(ev.t1 >= ev.t0);
            }
        }
    }

    #[test]
    fn one_f1b_step_time_is_comparable_to_gpipe() {
        // Both are flush schedules with the same (P-1)-slot bubble; step
        // times should be within a few percent of each other.
        let (g, pt, mut cfg) = base(4, 8);
        let gp = simulate_step(&g, &pt, &cfg);
        cfg.schedule = ScheduleKind::OneF1B;
        let f1b = simulate_step(&g, &pt, &cfg);
        let ratio = f1b.step_secs / gp.step_secs;
        assert!(
            (0.8..1.3).contains(&ratio),
            "1f1b/gpipe step ratio {ratio:.3} ({:.5}s vs {:.5}s)",
            f1b.step_secs,
            gp.step_secs
        );
    }
}
