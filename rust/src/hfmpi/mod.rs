//! `hfmpi` — an in-process MPI fabric.
//!
//! The paper runs on Intel MPI / MVAPICH2 across Stampede2 nodes; this repo
//! substitutes a from-scratch message-passing substrate where **ranks are OS
//! threads** inside one process. The substitution preserves everything the
//! paper's contribution actually exercises — communicators, tag-matched
//! blocking send/recv, collective algorithms, message-ordering/deadlock
//! semantics, communicator-per-partition layout, tensor fusion — and only
//! abstracts the wire. Multi-node behaviour is modeled separately by the
//! calibrated simulator (`crate::sim`).
//!
//! API mirrors the MPI subset HyPar-Flow's Communication Engine uses
//! (paper §6.3): `send`, `recv`, `broadcast`, `allreduce` (+ `barrier`,
//! `allgather`, `split`, `dup`), plus the nonblocking pair
//! `isend`/`wait` (MPI_Isend/MPI_Wait) the eager-send schedule programs
//! run on.
//!
//! Two point-to-point transports are implemented, selected per world
//! ([`Transport`]; env `HF_TRANSPORT=buffered|rendezvous`): **buffered**
//! (MPI_Bsend — sends complete on enqueue, waits are free) and
//! **rendezvous** (MPI_Ssend — a send completes only against the posted
//! matching receive; `isend` pins the payload and `wait` blocks until the
//! match, measuring real elapsed time). Payloads and per-key ordering are
//! identical, so any program that completes on both trains bitwise
//! identically on both.
//!
//! ```no_run
//! // (no_run: kept as documentation; the same code runs for real as
//! // `hfmpi::tests::allreduce_*`.)
//! use hyparflow::hfmpi::World;
//! use hyparflow::tensor::Tensor;
//! let outs = World::run(4, |comm| {
//!     let mut t = Tensor::full(&[2], comm.rank() as f32);
//!     comm.allreduce_sum(&mut t).unwrap();
//!     t.data[0]
//! });
//! assert!(outs.iter().all(|&x| x == 6.0)); // 0+1+2+3
//! ```

mod collectives;
mod fabric;
mod fusion;

pub use collectives::AllreduceAlgo;
pub use fabric::{Comm, CommStats, SendReq, Transport, World};
pub use fusion::{FusionBuffer, DEFAULT_THRESHOLD_BYTES};

/// Message tags used by the training engine. Kept here so every subsystem
/// agrees on the tag space (hfmpi itself reserves tags >= `RESERVED_BASE`
/// for collective internals).
pub mod tags {
    /// Forward-pass activation on a boundary/skip edge (+ edge id).
    pub const ACTIVATION: u64 = 1 << 20;
    /// Backward-pass partial error on a boundary/skip edge (+ edge id).
    pub const ERROR: u64 = 2 << 20;
    /// Initial weight broadcast (+ param id).
    pub const WEIGHTS: u64 = 3 << 20;
    /// Metrics reduction at the end of a step.
    pub const METRICS: u64 = 4 << 20;
    /// Label shipping from first to last partition (+ microbatch id).
    pub const LABELS: u64 = 5 << 20;
    /// Collective internals (reserved by hfmpi).
    pub const RESERVED_BASE: u64 = u64::MAX - (1 << 32);
}

#[cfg(test)]
mod tests;
