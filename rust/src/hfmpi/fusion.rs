//! Horovod-style tensor fusion (paper §5.3): pack many small gradient
//! tensors into flat buckets and run one allreduce per bucket, instead of
//! one per tensor. This amortizes per-message latency — the dominant cost
//! for deep models whose per-layer gradients are tiny (ResNet-110's median
//! conv gradient is 9 KiB).

use super::collectives::AllreduceAlgo;
use super::fabric::Comm;
use crate::tensor::{Shape, Tensor};

/// Default fusion threshold, matching Horovod's 64 MiB default.
pub const DEFAULT_THRESHOLD_BYTES: usize = 64 * 1024 * 1024;

/// Greedy packer: fills buckets up to `threshold_bytes` in tensor order
/// (order is deterministic so all replicas pack identically — required for
/// the allreduce contents to line up).
pub struct FusionBuffer {
    threshold_bytes: usize,
    algo: AllreduceAlgo,
}

impl FusionBuffer {
    pub fn new(threshold_bytes: usize, algo: AllreduceAlgo) -> Self {
        assert!(threshold_bytes > 0);
        FusionBuffer { threshold_bytes, algo }
    }

    pub fn with_defaults() -> Self {
        Self::new(DEFAULT_THRESHOLD_BYTES, AllreduceAlgo::Auto)
    }

    /// Mean-allreduce every tensor in `grads` across `comm`, fusing adjacent
    /// tensors into buckets of at most `threshold_bytes`. Returns the number
    /// of allreduce calls issued (for tests/benches).
    pub fn allreduce_mean(&self, comm: &Comm, grads: &mut [&mut Tensor]) -> anyhow::Result<usize> {
        let mut calls = 0;
        let mut start = 0;
        while start < grads.len() {
            // Grow the bucket [start, end).
            let mut end = start;
            let mut bytes = 0usize;
            while end < grads.len() {
                let b = grads[end].size_bytes();
                if end > start && bytes + b > self.threshold_bytes {
                    break;
                }
                bytes += b;
                end += 1;
            }
            if end - start == 1 {
                comm.allreduce_sum_with(grads[start], self.algo)?;
                grads[start].scale(1.0 / comm.size() as f32);
            } else {
                // Pack -> one allreduce -> unpack.
                let total: usize = grads[start..end].iter().map(|g| g.numel()).sum();
                let mut flat = Vec::with_capacity(total);
                for g in grads[start..end].iter() {
                    flat.extend_from_slice(&g.data);
                }
                let mut fused = Tensor::new(Shape::new(&[total]), flat);
                comm.allreduce_sum_with(&mut fused, self.algo)?;
                fused.scale(1.0 / comm.size() as f32);
                let mut off = 0;
                for g in grads[start..end].iter_mut() {
                    let n = g.numel();
                    g.data.copy_from_slice(&fused.data[off..off + n]);
                    off += n;
                }
            }
            calls += 1;
            start = end;
        }
        Ok(calls)
    }
}
