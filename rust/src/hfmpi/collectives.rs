//! Collective algorithms over the point-to-point fabric: barrier, broadcast,
//! allgather and three allreduce implementations (naive star, ring,
//! recursive doubling) with an auto-selection policy modeled on the choices
//! production MPI libraries make by message size.
//!
//! Every exchange-shaped step (barrier, allgather, ring, recursive
//! doubling) is written as a sendrecv — `isend` + `recv` + `wait` — never
//! as a blocking send followed by a recv: facing blocking sends form a
//! cycle that deadlocks on the rendezvous transport. The acyclic patterns
//! (binomial-tree bcast, star-gather naive allreduce) keep blocking sends.

use super::fabric::Comm;
use super::tags::RESERVED_BASE;
use crate::tensor::{Shape, Tensor};

/// Which allreduce algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllreduceAlgo {
    /// Gather to rank 0, reduce, broadcast. O(p) bandwidth at the root;
    /// only sensible for tiny messages / tiny communicators.
    Naive,
    /// Ring reduce-scatter + allgather: 2(p-1) steps, bandwidth-optimal for
    /// large messages (what Horovod/NCCL use).
    Ring,
    /// Recursive doubling: log2(p) steps, latency-optimal for small
    /// messages; requires (and is only selected for) power-of-two sizes.
    RecursiveDoubling,
    /// Pick by message size and communicator size.
    Auto,
}

/// Messages below this many bytes prefer latency-optimal algorithms.
const SMALL_MSG_BYTES: usize = 64 * 1024;

impl Comm {
    /// Synchronize all ranks of this communicator (dissemination barrier).
    pub fn barrier(&self) {
        let n = self.size();
        if n == 1 {
            return;
        }
        let me = self.rank();
        let token = Tensor::scalar(0.0);
        let mut round = 0u64;
        let mut dist = 1;
        while dist < n {
            let dst = (me + dist) % n;
            let src = (me + n - dist % n) % n;
            let req = self.isend(&token, dst, RESERVED_BASE + 100 + round);
            self.recv(src, RESERVED_BASE + 100 + round);
            self.wait(req);
            dist *= 2;
            round += 1;
        }
    }

    /// Broadcast `t` from `root` to all ranks (binomial tree). Each rank
    /// receives before it forwards, so the send graph is acyclic and the
    /// blocking sends below are rendezvous-safe.
    pub fn bcast(&self, t: &mut Tensor, root: usize) {
        let n = self.size();
        if n == 1 {
            return;
        }
        // Rotate so the root is virtual rank 0.
        let vrank = (self.rank() + n - root) % n;
        let tag = RESERVED_BASE + 200;
        let mut mask = 1;
        // Receive phase: find the bit that brings the data to us.
        while mask < n {
            if vrank & mask != 0 {
                let src_v = vrank ^ mask;
                let src = (src_v + root) % n;
                *t = self.recv(src, tag);
                break;
            }
            mask <<= 1;
        }
        // Send phase: forward to the subtree below us.
        let mut mask = mask >> 1;
        while mask > 0 {
            let dst_v = vrank | mask;
            if dst_v != vrank && dst_v < n {
                let dst = (dst_v + root) % n;
                self.send(t, dst, tag);
            }
            mask >>= 1;
        }
    }

    /// Gather every rank's tensor; returns them in rank order on all ranks.
    pub fn allgather(&self, t: &Tensor) -> Vec<Tensor> {
        let n = self.size();
        let me = self.rank();
        let tag = RESERVED_BASE + 300;
        // Simple ring circulation: n-1 steps, each forwards what it received.
        let mut out: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        out[me] = Some(t.clone());
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let mut carry = t.clone();
        for step in 0..n.saturating_sub(1) {
            let req = self.isend_owned(carry, right, tag + step as u64);
            carry = self.recv(left, tag + step as u64);
            self.wait(req);
            let origin = (me + n - 1 - step) % n;
            out[origin] = Some(carry.clone());
        }
        out.into_iter().map(|o| o.expect("allgather hole")).collect()
    }

    /// In-place sum-allreduce with the given algorithm.
    pub fn allreduce_sum_with(&self, t: &mut Tensor, algo: AllreduceAlgo) -> anyhow::Result<()> {
        let t0 = std::time::Instant::now();
        let n = self.size();
        let bytes = t.size_bytes() as u64;
        if n == 1 {
            self.note_allreduce(bytes, t0.elapsed().as_secs_f64());
            return Ok(());
        }
        let algo = match algo {
            AllreduceAlgo::Auto => {
                if t.size_bytes() <= SMALL_MSG_BYTES && n.is_power_of_two() {
                    AllreduceAlgo::RecursiveDoubling
                } else if n <= 3 {
                    AllreduceAlgo::Naive
                } else {
                    AllreduceAlgo::Ring
                }
            }
            a => a,
        };
        match algo {
            AllreduceAlgo::Naive => self.allreduce_naive(t),
            AllreduceAlgo::Ring => self.allreduce_ring(t),
            AllreduceAlgo::RecursiveDoubling => {
                if n.is_power_of_two() {
                    self.allreduce_recdbl(t)
                } else {
                    self.allreduce_ring(t)
                }
            }
            AllreduceAlgo::Auto => unreachable!(),
        }
        self.note_allreduce(bytes, t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// In-place sum-allreduce (auto algorithm).
    pub fn allreduce_sum(&self, t: &mut Tensor) -> anyhow::Result<()> {
        self.allreduce_sum_with(t, AllreduceAlgo::Auto)
    }

    /// In-place mean-allreduce (gradient averaging across model replicas).
    pub fn allreduce_mean(&self, t: &mut Tensor) -> anyhow::Result<()> {
        self.allreduce_sum(t)?;
        t.scale(1.0 / self.size() as f32);
        Ok(())
    }

    fn allreduce_naive(&self, t: &mut Tensor) {
        let n = self.size();
        let me = self.rank();
        let tag = RESERVED_BASE + 400;
        // Star into the root is acyclic: blocking sends are
        // rendezvous-safe here (the root posts the matching recvs).
        if me == 0 {
            for src in 1..n {
                let part = self.recv(src, tag);
                t.add_assign(&part);
            }
        } else {
            self.send(t, 0, tag);
        }
        self.bcast(t, 0);
    }

    /// Ring allreduce: reduce-scatter then allgather over uneven chunks.
    fn allreduce_ring(&self, t: &mut Tensor) {
        let n = self.size();
        let me = self.rank();
        let len = t.data.len();
        // Chunk boundaries: chunk c covers [start[c], start[c+1]).
        let starts: Vec<usize> = (0..=n).map(|c| c * len / n).collect();
        let right = (me + 1) % n;
        let left = (me + n - 1) % n;
        let tag = RESERVED_BASE + 500;

        // Reduce-scatter: after n-1 steps, rank r owns the full sum of
        // chunk (r+1) mod n.
        for step in 0..n - 1 {
            let send_c = (me + n - step) % n;
            let recv_c = (me + n - 1 - step) % n;
            let chunk =
                Tensor::new(Shape::new(&[starts[send_c + 1] - starts[send_c]]),
                            t.data[starts[send_c]..starts[send_c + 1]].to_vec());
            let req = self.isend_owned(chunk, right, tag + step as u64);
            let incoming = self.recv(left, tag + step as u64);
            self.wait(req);
            let dst = &mut t.data[starts[recv_c]..starts[recv_c + 1]];
            debug_assert_eq!(dst.len(), incoming.data.len());
            for (d, s) in dst.iter_mut().zip(incoming.data.iter()) {
                *d += *s;
            }
        }
        // Allgather: circulate the reduced chunks.
        for step in 0..n - 1 {
            let send_c = (me + 1 + n - step) % n;
            let recv_c = (me + n - step) % n;
            let chunk =
                Tensor::new(Shape::new(&[starts[send_c + 1] - starts[send_c]]),
                            t.data[starts[send_c]..starts[send_c + 1]].to_vec());
            let req = self.isend_owned(chunk, right, tag + 1000 + step as u64);
            let incoming = self.recv(left, tag + 1000 + step as u64);
            self.wait(req);
            let dst = &mut t.data[starts[recv_c]..starts[recv_c + 1]];
            dst.copy_from_slice(&incoming.data);
        }
    }

    /// Recursive doubling (power-of-two communicators only).
    fn allreduce_recdbl(&self, t: &mut Tensor) {
        let n = self.size();
        let me = self.rank();
        let tag = RESERVED_BASE + 600;
        let mut mask = 1;
        let mut round = 0u64;
        while mask < n {
            let peer = me ^ mask;
            let req = self.isend(t, peer, tag + round);
            let other = self.recv(peer, tag + round);
            self.wait(req);
            t.add_assign(&other);
            mask <<= 1;
            round += 1;
        }
    }
}
