//! The fabric: per-rank mailboxes, tag-matched blocking send/recv, and the
//! communicator machinery (world, dup, split) built on top.

use crate::tensor::Tensor;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Global rank id (thread index in the world).
pub type RankId = usize;

/// (source global rank, communicator id, tag) — the match key for recv.
type Key = (RankId, u64, u64);

/// Default deadlock watchdog: a blocking recv that waits longer than this
/// panics with a diagnostic instead of hanging the test suite forever.
/// Override with HFMPI_TIMEOUT_SECS.
const DEFAULT_TIMEOUT_SECS: u64 = 120;

fn recv_timeout() -> Duration {
    let secs = std::env::var("HFMPI_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_TIMEOUT_SECS);
    Duration::from_secs(secs)
}

struct Mailbox {
    queues: Mutex<HashMap<Key, VecDeque<Tensor>>>,
    cv: Condvar,
    timeout: Duration,
}

impl Mailbox {
    fn new(timeout: Duration) -> Self {
        Mailbox { queues: Mutex::new(HashMap::new()), cv: Condvar::new(), timeout }
    }

    fn push(&self, key: Key, msg: Tensor) {
        let mut q = self.queues.lock().unwrap();
        q.entry(key).or_default().push_back(msg);
        self.cv.notify_all();
    }

    fn pop_blocking(&self, key: Key, me: RankId) -> Tensor {
        let timeout = self.timeout;
        let mut q = self.queues.lock().unwrap();
        loop {
            if let Some(dq) = q.get_mut(&key) {
                if let Some(msg) = dq.pop_front() {
                    return msg;
                }
            }
            let (guard, res) = self.cv.wait_timeout(q, timeout).unwrap();
            q = guard;
            if res.timed_out() {
                let pending: Vec<Key> = q
                    .iter()
                    .filter(|(_, v)| !v.is_empty())
                    .map(|(k, _)| *k)
                    .collect();
                panic!(
                    "hfmpi deadlock watchdog: rank {me} blocked >{timeout:?} on \
                     recv(src={}, comm={}, tag={}); pending keys in mailbox: {pending:?}",
                    key.0, key.1, key.2
                );
            }
        }
    }
}

/// Rendezvous state for collective communicator creation (split).
struct SplitSlot {
    entries: HashMap<RankId, (i64, i64)>, // rank -> (color, key)
    result: Option<HashMap<RankId, (u64, Vec<RankId>)>>, // rank -> (comm id, members)
    arrived: usize,
}

/// Shared state for all ranks of a [`World`].
pub(crate) struct Fabric {
    mailboxes: Vec<Mailbox>,
    next_comm_id: AtomicU64,
    splits: Mutex<HashMap<(u64, u64), SplitSlot>>, // (parent comm, epoch) -> slot
    split_cv: Condvar,
    timeout: Duration,
}

impl Fabric {
    fn new(n: usize, timeout: Duration) -> Self {
        Fabric {
            mailboxes: (0..n).map(|_| Mailbox::new(timeout)).collect(),
            next_comm_id: AtomicU64::new(1),
            splits: Mutex::new(HashMap::new()),
            split_cv: Condvar::new(),
            timeout,
        }
    }
}

/// Per-rank, per-communicator statistics (bytes moved, call counts). The
/// engine reads these to report communication overhead in benches.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub sends: u64,
    pub recvs: u64,
    /// Nonblocking sends posted ([`Comm::isend`]); each also counts in
    /// `sends` on this buffered fabric.
    pub isends: u64,
    /// Nonblocking sends completed ([`Comm::wait`]). `isends == waits`
    /// after a drained step — the pairing invariant hftrace windows and
    /// the conformance tests check.
    pub waits: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub allreduces: u64,
    pub allreduce_bytes: u64,
    pub allreduce_secs: f64,
    pub send_secs: f64,
    pub recv_secs: f64,
}

/// A pending nonblocking send posted by [`Comm::isend`]; complete it with
/// [`Comm::wait`]. Dropping it without waiting leaks the completion
/// accounting, so it is `#[must_use]`.
#[must_use = "complete the send with Comm::wait"]
#[derive(Debug)]
pub struct SendReq {
    bytes: u64,
}

/// A communicator: an ordered group of global ranks plus this rank's index
/// within it. Cheap to clone (shares the fabric).
pub struct Comm {
    fabric: Arc<Fabric>,
    id: u64,
    /// Global rank ids of the members, in rank order.
    members: Vec<RankId>,
    /// This thread's index within `members`.
    my_idx: usize,
    stats: std::cell::RefCell<CommStats>,
    /// Per-rank epoch counters for split rendezvous on this comm.
    my_split_epoch: std::cell::Cell<u64>,
}

impl Comm {
    /// Rank within this communicator.
    pub fn rank(&self) -> usize {
        self.my_idx
    }

    /// Number of ranks in this communicator.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Global (world) rank of this thread.
    pub fn global_rank(&self) -> RankId {
        self.members[self.my_idx]
    }

    /// Global rank of communicator member `idx`.
    pub fn global_of(&self, idx: usize) -> RankId {
        self.members[idx]
    }

    /// Snapshot of this communicator's traffic counters.
    pub fn stats(&self) -> CommStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = CommStats::default();
    }

    /// Blocking tagged send to communicator rank `dst`.
    ///
    /// Mailboxes are unbounded, so "blocking" matches MPI's buffered-send
    /// semantics: the call returns once the message is enqueued. Ordering
    /// between a (src, tag) pair is FIFO.
    pub fn send(&self, t: &Tensor, dst: usize, tag: u64) {
        let t0 = std::time::Instant::now();
        let dst_global = self.members[dst];
        let key = (self.global_rank(), self.id, tag);
        self.fabric.mailboxes[dst_global].push(key, t.clone());
        let mut s = self.stats.borrow_mut();
        s.sends += 1;
        s.bytes_sent += t.size_bytes() as u64;
        s.send_secs += t0.elapsed().as_secs_f64();
    }

    /// Move-variant of [`send`](Self::send): avoids cloning the payload.
    pub fn send_owned(&self, t: Tensor, dst: usize, tag: u64) {
        let t0 = std::time::Instant::now();
        let bytes = t.size_bytes() as u64;
        let dst_global = self.members[dst];
        let key = (self.global_rank(), self.id, tag);
        self.fabric.mailboxes[dst_global].push(key, t);
        let mut s = self.stats.borrow_mut();
        s.sends += 1;
        s.bytes_sent += bytes;
        s.send_secs += t0.elapsed().as_secs_f64();
    }

    /// Nonblocking tagged send (MPI_Isend): initiate the transfer and
    /// return a request handle immediately; [`Comm::wait`] completes it.
    /// On this buffered fabric the payload is enqueued at post time, so
    /// the request is already complete when returned — `wait` exists for
    /// the MPI contract and for symmetry with rendezvous transports, where
    /// it would block until the matching receive is posted. Callers must
    /// keep their payload buffer untouched until the wait (the engine pins
    /// error payloads inside its `SendHandle` for exactly this reason).
    pub fn isend(&self, t: &Tensor, dst: usize, tag: u64) -> SendReq {
        let bytes = t.size_bytes() as u64;
        self.send(t, dst, tag);
        self.stats.borrow_mut().isends += 1;
        SendReq { bytes }
    }

    /// Complete a nonblocking send. Returns the payload size in bytes.
    pub fn wait(&self, req: SendReq) -> u64 {
        self.stats.borrow_mut().waits += 1;
        req.bytes
    }

    /// Blocking tagged receive from communicator rank `src`.
    pub fn recv(&self, src: usize, tag: u64) -> Tensor {
        let t0 = std::time::Instant::now();
        let me = self.global_rank();
        let src_global = self.members[src];
        let key = (src_global, self.id, tag);
        let t = self.fabric.mailboxes[me].pop_blocking(key, me);
        let mut s = self.stats.borrow_mut();
        s.recvs += 1;
        s.bytes_recv += t.size_bytes() as u64;
        s.recv_secs += t0.elapsed().as_secs_f64();
        t
    }

    /// Duplicate this communicator (fresh id, same members). Collective.
    pub fn dup(&self) -> Comm {
        self.split(0, self.my_idx as i64)
    }

    /// MPI_Comm_split: collective over all members of this communicator.
    /// Ranks passing the same `color` land in the same new communicator,
    /// ordered by `key` (ties broken by parent rank).
    pub fn split(&self, color: i64, key: i64) -> Comm {
        let epoch = self.my_split_epoch.get();
        self.my_split_epoch.set(epoch + 1);
        let slot_key = (self.id, epoch);
        let me = self.global_rank();
        let n = self.size();

        let mut splits = self.fabric.splits.lock().unwrap();
        {
            let slot = splits.entry(slot_key).or_insert_with(|| SplitSlot {
                entries: HashMap::new(),
                result: None,
                arrived: 0,
            });
            slot.entries.insert(me, (color, key));
            slot.arrived += 1;
            if slot.arrived == n {
                // Last arrival computes the grouping for everyone.
                let mut groups: HashMap<i64, Vec<(i64, usize, RankId)>> = HashMap::new();
                for (idx, &g) in self.members.iter().enumerate() {
                    let (c, k) = slot.entries[&g];
                    groups.entry(c).or_default().push((k, idx, g));
                }
                let mut result = HashMap::new();
                let mut colors: Vec<i64> = groups.keys().copied().collect();
                colors.sort();
                for c in colors {
                    let mut v = groups.remove(&c).unwrap();
                    v.sort(); // by (key, parent idx)
                    let members: Vec<RankId> = v.iter().map(|&(_, _, g)| g).collect();
                    let id = self.fabric.next_comm_id.fetch_add(1, Ordering::SeqCst);
                    for &g in &members {
                        result.insert(g, (id, members.clone()));
                    }
                }
                slot.result = Some(result);
                self.fabric.split_cv.notify_all();
            }
        }
        // Wait for the grouping to be published.
        let (id, members) = loop {
            if let Some(slot) = splits.get(&slot_key) {
                if let Some(res) = &slot.result {
                    break res[&me].clone();
                }
            }
            let timeout = self.fabric.timeout;
            let (guard, res) = self.fabric.split_cv.wait_timeout(splits, timeout).unwrap();
            splits = guard;
            if res.timed_out() {
                panic!("hfmpi: rank {me} timed out in split on comm {}", self.id);
            }
        };
        let my_idx = members.iter().position(|&g| g == me).unwrap();
        Comm {
            fabric: Arc::clone(&self.fabric),
            id,
            members,
            my_idx,
            stats: Default::default(),
            my_split_epoch: std::cell::Cell::new(0),
        }
    }

    /// Record an allreduce in the stats (used by the collectives module).
    pub(crate) fn note_allreduce(&self, bytes: u64, secs: f64) {
        let mut s = self.stats.borrow_mut();
        s.allreduces += 1;
        s.allreduce_bytes += bytes;
        s.allreduce_secs += secs;
    }
}

/// The world: spawns `n` rank threads and hands each its world communicator.
pub struct World;

impl World {
    /// Run `f` on `n` rank threads; returns each rank's result in rank order.
    /// Panics in any rank propagate (failing the test/run) once all threads
    /// finish or the watchdog fires.
    pub fn run<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        Self::run_with_timeout(n, recv_timeout(), f)
    }

    /// [`run`](Self::run) with an explicit deadlock-watchdog timeout.
    pub fn run_with_timeout<T, F>(n: usize, timeout: Duration, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        assert!(n > 0, "world size must be positive");
        let fabric = Arc::new(Fabric::new(n, timeout));
        let members: Vec<RankId> = (0..n).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for r in 0..n {
                let fabric = Arc::clone(&fabric);
                let members = members.clone();
                let f = &f;
                handles.push(scope.spawn(move || {
                    let comm = Comm {
                        fabric,
                        id: 0,
                        members,
                        my_idx: r,
                        stats: Default::default(),
                        my_split_epoch: std::cell::Cell::new(0),
                    };
                    f(&comm)
                }));
            }
            handles
                .into_iter()
                .enumerate()
                .map(|(r, h)| match h.join() {
                    Ok(v) => v,
                    Err(e) => {
                        let msg = e
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".into());
                        panic!("rank {r} panicked: {msg}")
                    }
                })
                .collect()
        })
    }
}
