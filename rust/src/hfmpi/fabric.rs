//! The fabric: per-rank mailboxes, tag-matched send/recv with two
//! point-to-point transports (buffered and rendezvous), and the
//! communicator machinery (world, dup, split) built on top.
//!
//! # Transports
//!
//! Every message takes the same path — the sender deposits the payload in
//! the receiver's mailbox under a `(src, comm, tag)` key, the receiver
//! pops it FIFO per key — but *when a send completes* differs:
//!
//! - [`Transport::Buffered`] (MPI_Bsend): `send` returns once the message
//!   is enqueued, `isend` is complete at post time and `wait` is free.
//! - [`Transport::Rendezvous`] (MPI_Ssend / the paper's §6.3 setting for
//!   large messages): `send` blocks until the matching `recv` consumes
//!   the payload; `isend` registers a pending entry (the payload is
//!   pinned in the mailbox) and returns immediately; `wait` blocks until
//!   the match completes. Facing blocking sends therefore deadlock —
//!   which is exactly the 1F1B-family hazard `Program::check` analyses,
//!   now executable against the live fabric.
//!
//! Payloads, arithmetic and per-key message order are identical under
//! both transports, so training results are bitwise equal whenever a
//! program completes on both.

use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Global rank id (thread index in the world).
pub type RankId = usize;

/// (source global rank, communicator id, tag) — the match key for recv.
type Key = (RankId, u64, u64);

/// Default deadlock watchdog: a blocking recv/send/wait that waits longer
/// than this panics with a diagnostic instead of hanging the test suite
/// forever. Override with HFMPI_TIMEOUT_SECS.
const DEFAULT_TIMEOUT_SECS: u64 = 120;

/// Watchdog timeout from the environment. Strict per the repo's env
/// policy: an unparseable `HFMPI_TIMEOUT_SECS` is a hard error naming the
/// variable, never a silent fallback to the default.
pub(crate) fn recv_timeout() -> Duration {
    let secs = crate::util::env_parse("HFMPI_TIMEOUT_SECS", DEFAULT_TIMEOUT_SECS)
        .unwrap_or_else(|e| panic!("{e:#}"));
    Duration::from_secs(secs)
}

/// Point-to-point send-completion semantics, selected per [`World`]
/// (`HF_TRANSPORT` or [`World::run_with_transport`]). See the module docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Transport {
    /// Sends complete on enqueue; `wait` is free. The historical fabric
    /// behavior and the default.
    #[default]
    Buffered,
    /// Sends complete only against the matching posted receive; `wait`
    /// blocks until then and measures real elapsed time.
    Rendezvous,
}

impl Transport {
    pub fn parse(s: &str) -> anyhow::Result<Transport> {
        match s {
            "buffered" => Ok(Transport::Buffered),
            "rendezvous" => Ok(Transport::Rendezvous),
            other => anyhow::bail!(
                "unrecognized transport {other:?} (valid values: buffered|rendezvous)"
            ),
        }
    }

    /// Strict `HF_TRANSPORT` read: absent means buffered, anything
    /// unrecognized is a hard error (same policy as `util::env_flag`).
    pub fn from_env() -> anyhow::Result<Transport> {
        match std::env::var("HF_TRANSPORT") {
            Err(std::env::VarError::NotPresent) => Ok(Transport::default()),
            Err(std::env::VarError::NotUnicode(v)) => {
                anyhow::bail!("HF_TRANSPORT={v:?} is not unicode")
            }
            Ok(v) => Transport::parse(&v).map_err(|e| anyhow::anyhow!("HF_TRANSPORT: {e}")),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Transport::Buffered => "buffered",
            Transport::Rendezvous => "rendezvous",
        }
    }
}

fn env_transport() -> Transport {
    Transport::from_env().unwrap_or_else(|e| panic!("{e:#}"))
}

/// Poison-tolerant lock. A watchdog panic in one rank (possibly caught by
/// a test) poisons the mutex it held, but every panic site leaves the
/// guarded state fully consistent — so other ranks keep going instead of
/// cascading poison panics.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant condvar wait (see [`lock_ignore_poison`]).
fn wait_ignore_poison<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, T>,
    timeout: Duration,
) -> std::sync::MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

/// A message parked in a mailbox: the payload plus the fabric-wide send
/// sequence id its sender may be blocked on (rendezvous completion).
struct InFlight {
    seq: u64,
    payload: Tensor,
}

/// Mailbox contents, guarded by one mutex so matching and completion are
/// a single state machine: `pending` holds posted-but-unreceived messages,
/// `done` the sequence ids whose message a recv has consumed (rendezvous
/// only — buffered sends never look, so tracking them would only leak).
struct MailboxState {
    pending: HashMap<Key, VecDeque<InFlight>>,
    done: HashSet<u64>,
}

struct Mailbox {
    state: Mutex<MailboxState>,
    cv: Condvar,
    timeout: Duration,
    transport: Transport,
}

impl Mailbox {
    fn new(timeout: Duration, transport: Transport) -> Self {
        Mailbox {
            state: Mutex::new(MailboxState { pending: HashMap::new(), done: HashSet::new() }),
            cv: Condvar::new(),
            timeout,
            transport,
        }
    }

    fn push(&self, key: Key, seq: u64, payload: Tensor) {
        let mut st = lock_ignore_poison(&self.state);
        st.pending.entry(key).or_default().push_back(InFlight { seq, payload });
        self.cv.notify_all();
    }

    /// Blocking receive with an absolute-deadline watchdog: the deadline
    /// is fixed on entry, so unrelated traffic waking the condvar cannot
    /// postpone the panic. (The previous per-wakeup timeout restart meant
    /// a starved rank in a busy world was never caught.)
    fn pop_blocking(&self, key: Key, me: RankId) -> Tensor {
        let deadline = Instant::now() + self.timeout;
        let mut st = lock_ignore_poison(&self.state);
        loop {
            if let Some(dq) = st.pending.get_mut(&key) {
                if let Some(m) = dq.pop_front() {
                    if dq.is_empty() {
                        st.pending.remove(&key);
                    }
                    if self.transport == Transport::Rendezvous {
                        // Complete the sender: it may be blocked in
                        // send/wait on this seq.
                        st.done.insert(m.seq);
                        self.cv.notify_all();
                    }
                    return m.payload;
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                let pending: Vec<Key> = st
                    .pending
                    .iter()
                    .filter(|(_, v)| !v.is_empty())
                    .map(|(k, _)| *k)
                    .collect();
                panic!(
                    "hfmpi deadlock watchdog: rank {me} blocked >{:?} on \
                     recv(src={}, comm={}, tag={}); pending keys in mailbox: {pending:?}",
                    self.timeout, key.0, key.1, key.2
                );
            }
            st = wait_ignore_poison(&self.cv, st, remaining);
        }
    }

    /// Rendezvous completion: block until the receiver consumed send
    /// `seq`. Same absolute-deadline watchdog as `pop_blocking`.
    fn wait_done(&self, seq: u64, me: RankId, op: &str, dst: RankId, comm: u64, tag: u64) {
        let deadline = Instant::now() + self.timeout;
        let mut st = lock_ignore_poison(&self.state);
        loop {
            if st.done.remove(&seq) {
                return;
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                panic!(
                    "hfmpi deadlock watchdog: rank {me} blocked >{:?} in rendezvous \
                     {op}(dst={dst}, comm={comm}, tag={tag}): the matching recv was \
                     never posted",
                    self.timeout
                );
            }
            st = wait_ignore_poison(&self.cv, st, remaining);
        }
    }
}

/// Rendezvous state for collective communicator creation (split).
struct SplitSlot {
    entries: HashMap<RankId, (i64, i64)>, // rank -> (color, key)
    result: Option<HashMap<RankId, (u64, Vec<RankId>)>>, // rank -> (comm id, members)
    arrived: usize,
    /// Ranks that have read their result; the last reader removes the
    /// slot (a long-lived world splitting repeatedly must not grow the
    /// map without bound).
    read: usize,
}

/// Shared state for all ranks of a [`World`].
pub(crate) struct Fabric {
    mailboxes: Vec<Mailbox>,
    next_comm_id: AtomicU64,
    /// Fabric-wide send sequence ids (rendezvous completion tracking).
    next_send_seq: AtomicU64,
    splits: Mutex<HashMap<(u64, u64), SplitSlot>>, // (parent comm, epoch) -> slot
    split_cv: Condvar,
    timeout: Duration,
    transport: Transport,
}

impl Fabric {
    fn new(n: usize, timeout: Duration, transport: Transport) -> Self {
        Fabric {
            mailboxes: (0..n).map(|_| Mailbox::new(timeout, transport)).collect(),
            next_comm_id: AtomicU64::new(1),
            next_send_seq: AtomicU64::new(0),
            splits: Mutex::new(HashMap::new()),
            split_cv: Condvar::new(),
            timeout,
            transport,
        }
    }
}

/// Per-rank, per-communicator statistics (bytes moved, call counts). The
/// engine reads these to report communication overhead in benches.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Completed sends. Blocking sends count on return; `isend`s count at
    /// post time on the buffered transport and at match time (inside
    /// [`Comm::wait`]) under rendezvous — `bytes_sent` and `send_secs`
    /// follow the same rule, so under rendezvous they measure real
    /// transfer completion.
    pub sends: u64,
    pub recvs: u64,
    /// Nonblocking sends posted ([`Comm::isend`]).
    pub isends: u64,
    /// Nonblocking sends completed ([`Comm::wait`]). `isends == waits`
    /// after a drained step — the pairing invariant hftrace windows and
    /// the conformance tests check.
    pub waits: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub allreduces: u64,
    pub allreduce_bytes: u64,
    pub allreduce_secs: f64,
    pub send_secs: f64,
    pub recv_secs: f64,
}

/// An unmatched rendezvous isend: what [`Comm::wait`] must block on.
#[derive(Debug)]
struct PendingSend {
    /// Destination *global* rank — whose mailbox owns the match state.
    dst: RankId,
    seq: u64,
    tag: u64,
}

/// A pending nonblocking send posted by [`Comm::isend`]; complete it with
/// [`Comm::wait`]. Dropping it without waiting leaks the completion
/// accounting (and, under rendezvous, abandons a sender-side completion
/// that the transfer semantics require), so it is `#[must_use]`.
#[must_use = "complete the send with Comm::wait"]
#[derive(Debug)]
pub struct SendReq {
    bytes: u64,
    /// `Some` iff the send is not yet complete (rendezvous posts).
    pending: Option<PendingSend>,
}

/// A communicator: an ordered group of global ranks plus this rank's index
/// within it. Cheap to clone (shares the fabric).
pub struct Comm {
    fabric: Arc<Fabric>,
    id: u64,
    /// Global rank ids of the members, in rank order.
    members: Vec<RankId>,
    /// This thread's index within `members`.
    my_idx: usize,
    stats: std::cell::RefCell<CommStats>,
    /// Per-rank epoch counters for split rendezvous on this comm.
    my_split_epoch: std::cell::Cell<u64>,
}

impl Comm {
    /// Rank within this communicator.
    pub fn rank(&self) -> usize {
        self.my_idx
    }

    /// Number of ranks in this communicator.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Global (world) rank of this thread.
    pub fn global_rank(&self) -> RankId {
        self.members[self.my_idx]
    }

    /// Global rank of communicator member `idx`.
    pub fn global_of(&self, idx: usize) -> RankId {
        self.members[idx]
    }

    /// The world's point-to-point transport semantics.
    pub fn transport(&self) -> Transport {
        self.fabric.transport
    }

    /// Snapshot of this communicator's traffic counters.
    pub fn stats(&self) -> CommStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = CommStats::default();
    }

    /// Deposit a payload in `dst`'s mailbox; returns what completion
    /// tracking needs. The common first half of every send flavor.
    fn post(&self, t: Tensor, dst: usize, tag: u64) -> (RankId, u64, u64) {
        let bytes = t.size_bytes() as u64;
        let dst_global = self.members[dst];
        let key = (self.global_rank(), self.id, tag);
        let seq = self.fabric.next_send_seq.fetch_add(1, Ordering::Relaxed);
        self.fabric.mailboxes[dst_global].push(key, seq, t);
        (dst_global, seq, bytes)
    }

    /// Blocking tagged send to communicator rank `dst`.
    ///
    /// Buffered transport: mailboxes are unbounded, so the call returns
    /// once the message is enqueued (MPI buffered-send semantics).
    /// Rendezvous transport: blocks until the matching `recv` consumes
    /// the payload (MPI synchronous-send semantics) — facing blocking
    /// sends deadlock and the watchdog fires. Ordering between a
    /// (src, tag) pair is FIFO under both.
    pub fn send(&self, t: &Tensor, dst: usize, tag: u64) {
        self.send_owned(t.clone(), dst, tag)
    }

    /// Move-variant of [`send`](Self::send): avoids cloning the payload.
    pub fn send_owned(&self, t: Tensor, dst: usize, tag: u64) {
        let t0 = Instant::now();
        let (dst_global, seq, bytes) = self.post(t, dst, tag);
        if self.fabric.transport == Transport::Rendezvous {
            self.fabric.mailboxes[dst_global].wait_done(
                seq,
                self.global_rank(),
                "send",
                dst_global,
                self.id,
                tag,
            );
        }
        let mut s = self.stats.borrow_mut();
        s.sends += 1;
        s.bytes_sent += bytes;
        s.send_secs += t0.elapsed().as_secs_f64();
    }

    /// Nonblocking tagged send (MPI_Isend): initiate the transfer and
    /// return a request handle immediately; [`Comm::wait`] completes it.
    /// The fabric pins a copy of the payload at post time, so the caller's
    /// buffer is free to reuse — stronger than the MPI contract, which the
    /// engine still honors by pinning payloads in its `SendHandle`.
    ///
    /// Buffered: the request is already complete when returned and `wait`
    /// is free. Rendezvous: the request completes when the receiver's
    /// `recv` consumes the payload; `wait` blocks until then and the
    /// send's `CommStats` accounting happens at that match time.
    pub fn isend(&self, t: &Tensor, dst: usize, tag: u64) -> SendReq {
        self.isend_owned(t.clone(), dst, tag)
    }

    /// Move-variant of [`isend`](Self::isend): avoids cloning the payload.
    pub fn isend_owned(&self, t: Tensor, dst: usize, tag: u64) -> SendReq {
        let t0 = Instant::now();
        let (dst_global, seq, bytes) = self.post(t, dst, tag);
        let mut s = self.stats.borrow_mut();
        s.isends += 1;
        match self.fabric.transport {
            Transport::Buffered => {
                // Complete at post: count the send now.
                s.sends += 1;
                s.bytes_sent += bytes;
                s.send_secs += t0.elapsed().as_secs_f64();
                SendReq { bytes, pending: None }
            }
            Transport::Rendezvous => {
                SendReq { bytes, pending: Some(PendingSend { dst: dst_global, seq, tag }) }
            }
        }
    }

    /// Complete a nonblocking send. Blocks until the match under
    /// rendezvous (free on buffered). Returns the payload size in bytes.
    pub fn wait(&self, req: SendReq) -> u64 {
        let t0 = Instant::now();
        if let Some(p) = &req.pending {
            self.fabric.mailboxes[p.dst].wait_done(
                p.seq,
                self.global_rank(),
                "wait",
                p.dst,
                self.id,
                p.tag,
            );
            // Match-time accounting: the transfer completed here.
            let mut s = self.stats.borrow_mut();
            s.sends += 1;
            s.bytes_sent += req.bytes;
            s.send_secs += t0.elapsed().as_secs_f64();
        }
        self.stats.borrow_mut().waits += 1;
        req.bytes
    }

    /// Blocking tagged receive from communicator rank `src`.
    pub fn recv(&self, src: usize, tag: u64) -> Tensor {
        let t0 = Instant::now();
        let me = self.global_rank();
        let src_global = self.members[src];
        let key = (src_global, self.id, tag);
        let t = self.fabric.mailboxes[me].pop_blocking(key, me);
        let mut s = self.stats.borrow_mut();
        s.recvs += 1;
        s.bytes_recv += t.size_bytes() as u64;
        s.recv_secs += t0.elapsed().as_secs_f64();
        t
    }

    /// Duplicate this communicator (fresh id, same members). Collective.
    pub fn dup(&self) -> Comm {
        self.split(0, self.my_idx as i64)
    }

    /// MPI_Comm_split: collective over all members of this communicator.
    /// Ranks passing the same `color` land in the same new communicator,
    /// ordered by `key` (ties broken by parent rank).
    pub fn split(&self, color: i64, key: i64) -> Comm {
        let epoch = self.my_split_epoch.get();
        self.my_split_epoch.set(epoch + 1);
        let slot_key = (self.id, epoch);
        let me = self.global_rank();
        let n = self.size();

        let mut splits = lock_ignore_poison(&self.fabric.splits);
        {
            let slot = splits.entry(slot_key).or_insert_with(|| SplitSlot {
                entries: HashMap::new(),
                result: None,
                arrived: 0,
                read: 0,
            });
            slot.entries.insert(me, (color, key));
            slot.arrived += 1;
            if slot.arrived == n {
                // Last arrival computes the grouping for everyone.
                let mut groups: HashMap<i64, Vec<(i64, usize, RankId)>> = HashMap::new();
                for (idx, &g) in self.members.iter().enumerate() {
                    let (c, k) = slot.entries[&g];
                    groups.entry(c).or_default().push((k, idx, g));
                }
                let mut result = HashMap::new();
                let mut colors: Vec<i64> = groups.keys().copied().collect();
                colors.sort();
                for c in colors {
                    let mut v = groups.remove(&c).unwrap();
                    v.sort(); // by (key, parent idx)
                    let members: Vec<RankId> = v.iter().map(|&(_, _, g)| g).collect();
                    let id = self.fabric.next_comm_id.fetch_add(1, Ordering::SeqCst);
                    for &g in &members {
                        result.insert(g, (id, members.clone()));
                    }
                }
                slot.result = Some(result);
                self.fabric.split_cv.notify_all();
            }
        }
        // Wait for the grouping to be published. Absolute deadline: every
        // split completing anywhere on the fabric notifies this condvar,
        // so a per-wakeup timeout restart would never catch a starved
        // rank in a world that keeps splitting elsewhere.
        let deadline = Instant::now() + self.fabric.timeout;
        let (id, members) = loop {
            if let Some(slot) = splits.get_mut(&slot_key) {
                if let Some(res) = &slot.result {
                    let mine = res[&me].clone();
                    // Last reader garbage-collects the slot.
                    slot.read += 1;
                    if slot.read == n {
                        splits.remove(&slot_key);
                    }
                    break mine;
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                panic!(
                    "hfmpi deadlock watchdog: rank {me} blocked >{:?} in split on \
                     comm {} (epoch {epoch}): not all members called split",
                    self.fabric.timeout, self.id
                );
            }
            splits = wait_ignore_poison(&self.fabric.split_cv, splits, remaining);
        };
        let my_idx = members.iter().position(|&g| g == me).unwrap();
        Comm {
            fabric: Arc::clone(&self.fabric),
            id,
            members,
            my_idx,
            stats: Default::default(),
            my_split_epoch: std::cell::Cell::new(0),
        }
    }

    /// Number of live split-rendezvous slots on the fabric (test hook for
    /// the slot garbage collection).
    #[cfg(test)]
    pub(crate) fn debug_split_slots(&self) -> usize {
        lock_ignore_poison(&self.fabric.splits).len()
    }

    /// Record an allreduce in the stats (used by the collectives module).
    pub(crate) fn note_allreduce(&self, bytes: u64, secs: f64) {
        let mut s = self.stats.borrow_mut();
        s.allreduces += 1;
        s.allreduce_bytes += bytes;
        s.allreduce_secs += secs;
    }
}

/// The world: spawns `n` rank threads and hands each its world communicator.
pub struct World;

impl World {
    /// Run `f` on `n` rank threads; returns each rank's result in rank order.
    /// Panics in any rank propagate (failing the test/run) once all threads
    /// finish or the watchdog fires. Transport and watchdog timeout come
    /// from the environment (`HF_TRANSPORT`, `HFMPI_TIMEOUT_SECS`).
    pub fn run<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        Self::run_with(n, env_transport(), None, f)
    }

    /// [`run`](Self::run) with an explicit deadlock-watchdog timeout.
    pub fn run_with_timeout<T, F>(n: usize, timeout: Duration, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        Self::run_with(n, env_transport(), Some(timeout), f)
    }

    /// [`run`](Self::run) with an explicit point-to-point transport.
    pub fn run_with_transport<T, F>(n: usize, transport: Transport, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        Self::run_with(n, transport, None, f)
    }

    /// Full-control spawn: explicit transport and watchdog timeout
    /// (`None` = `HFMPI_TIMEOUT_SECS`, default 120s).
    pub fn run_with<T, F>(n: usize, transport: Transport, timeout: Option<Duration>, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&Comm) -> T + Sync,
    {
        assert!(n > 0, "world size must be positive");
        let timeout = timeout.unwrap_or_else(recv_timeout);
        let fabric = Arc::new(Fabric::new(n, timeout, transport));
        let members: Vec<RankId> = (0..n).collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for r in 0..n {
                let fabric = Arc::clone(&fabric);
                let members = members.clone();
                let f = &f;
                handles.push(scope.spawn(move || {
                    let comm = Comm {
                        fabric,
                        id: 0,
                        members,
                        my_idx: r,
                        stats: Default::default(),
                        my_split_epoch: std::cell::Cell::new(0),
                    };
                    f(&comm)
                }));
            }
            handles
                .into_iter()
                .enumerate()
                .map(|(r, h)| match h.join() {
                    Ok(v) => v,
                    Err(e) => {
                        let msg = e
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".into());
                        panic!("rank {r} panicked: {msg}")
                    }
                })
                .collect()
        })
    }
}
