//! Unit tests for the hfmpi fabric: point-to-point semantics, communicator
//! splitting, every collective algorithm, and the fusion buffer.

use super::*;
use crate::tensor::Tensor;

#[test]
fn send_recv_basic() {
    World::run(2, |c| {
        if c.rank() == 0 {
            c.send(&Tensor::full(&[3], 7.0), 1, 42);
        } else {
            let t = c.recv(0, 42);
            assert_eq!(t.data, vec![7.0; 3]);
        }
    });
}

#[test]
fn send_recv_fifo_order_per_tag() {
    World::run(2, |c| {
        if c.rank() == 0 {
            for i in 0..10 {
                c.send(&Tensor::scalar(i as f32), 1, 5);
            }
        } else {
            for i in 0..10 {
                assert_eq!(c.recv(0, 5).data[0], i as f32);
            }
        }
    });
}

#[test]
fn tags_do_not_cross_match() {
    World::run(2, |c| {
        if c.rank() == 0 {
            c.send(&Tensor::scalar(1.0), 1, 100);
            c.send(&Tensor::scalar(2.0), 1, 200);
        } else {
            // Receive in reverse tag order: matching must be by tag.
            assert_eq!(c.recv(0, 200).data[0], 2.0);
            assert_eq!(c.recv(0, 100).data[0], 1.0);
        }
    });
}

#[test]
fn sends_from_different_sources_do_not_cross_match() {
    World::run(3, |c| {
        match c.rank() {
            0 => c.send(&Tensor::scalar(10.0), 2, 7),
            1 => c.send(&Tensor::scalar(20.0), 2, 7),
            _ => {
                assert_eq!(c.recv(1, 7).data[0], 20.0);
                assert_eq!(c.recv(0, 7).data[0], 10.0);
            }
        }
    });
}

#[test]
fn barrier_all_sizes() {
    for n in [1, 2, 3, 4, 7, 8] {
        World::run(n, |c| {
            for _ in 0..3 {
                c.barrier();
            }
        });
    }
}

#[test]
fn bcast_from_each_root() {
    for n in [1, 2, 3, 5, 8] {
        for root in 0..n {
            World::run(n, move |c| {
                let mut t = if c.rank() == root {
                    Tensor::full(&[4], 3.5)
                } else {
                    Tensor::zeros(&[4])
                };
                c.bcast(&mut t, root);
                assert_eq!(t.data, vec![3.5; 4], "n={n} root={root} rank={}", c.rank());
            });
        }
    }
}

#[test]
fn allgather_rank_order() {
    for n in [1, 2, 3, 6] {
        World::run(n, |c| {
            let mine = Tensor::scalar(c.rank() as f32);
            let all = c.allgather(&mine);
            let got: Vec<f32> = all.iter().map(|t| t.data[0]).collect();
            let want: Vec<f32> = (0..n).map(|r| r as f32).collect();
            assert_eq!(got, want);
        });
    }
}

fn check_allreduce(n: usize, len: usize, algo: AllreduceAlgo) {
    World::run(n, move |c| {
        let mut t = Tensor::new(
            crate::tensor::Shape::new(&[len]),
            (0..len).map(|i| (c.rank() + 1) as f32 * (i + 1) as f32).collect(),
        );
        c.allreduce_sum_with(&mut t, algo).unwrap();
        let rank_sum: f32 = (1..=n).sum::<usize>() as f32;
        for (i, v) in t.data.iter().enumerate() {
            let want = rank_sum * (i + 1) as f32;
            assert!((v - want).abs() < 1e-3, "n={n} len={len} algo={algo:?} i={i}: {v} != {want}");
        }
    });
}

#[test]
fn allreduce_naive() {
    for n in [1, 2, 3, 4, 5] {
        check_allreduce(n, 17, AllreduceAlgo::Naive);
    }
}

#[test]
fn allreduce_ring() {
    // Includes len < n (empty chunks) and len not divisible by n.
    for n in [2, 3, 4, 5, 8] {
        for len in [1, 3, 64, 1000] {
            check_allreduce(n, len, AllreduceAlgo::Ring);
        }
    }
}

#[test]
fn allreduce_recursive_doubling() {
    for n in [2, 4, 8] {
        check_allreduce(n, 33, AllreduceAlgo::RecursiveDoubling);
    }
    // Non-power-of-two silently falls back to ring.
    check_allreduce(3, 33, AllreduceAlgo::RecursiveDoubling);
}

#[test]
fn allreduce_auto() {
    for n in [2, 3, 4, 6, 8] {
        check_allreduce(n, 100, AllreduceAlgo::Auto);
        check_allreduce(n, 100_000, AllreduceAlgo::Auto);
    }
}

#[test]
fn allreduce_mean_averages() {
    World::run(4, |c| {
        let mut t = Tensor::full(&[8], c.rank() as f32);
        c.allreduce_mean(&mut t).unwrap();
        assert_eq!(t.data, vec![1.5; 8]); // mean(0,1,2,3)
    });
}

#[test]
fn split_by_color_groups_and_orders() {
    // 6 ranks, color = rank % 2 -> two comms of 3 ordered by rank.
    World::run(6, |c| {
        let sub = c.split((c.rank() % 2) as i64, c.rank() as i64);
        assert_eq!(sub.size(), 3);
        assert_eq!(sub.rank(), c.rank() / 2);
        // Collectives work inside the sub-communicator.
        let mut t = Tensor::scalar(c.rank() as f32);
        sub.allreduce_sum(&mut t).unwrap();
        let want = if c.rank() % 2 == 0 { 0. + 2. + 4. } else { 1. + 3. + 5. };
        assert_eq!(t.data[0], want);
    });
}

#[test]
fn split_key_reorders_ranks() {
    World::run(4, |c| {
        // All same color; key = -rank reverses the ordering.
        let sub = c.split(0, -(c.rank() as i64));
        assert_eq!(sub.rank(), 3 - c.rank());
    });
}

#[test]
fn repeated_splits_are_independent() {
    World::run(4, |c| {
        let a = c.split((c.rank() % 2) as i64, 0);
        let b = c.split((c.rank() / 2) as i64, 0);
        let mut ta = Tensor::scalar(1.0);
        let mut tb = Tensor::scalar(1.0);
        a.allreduce_sum(&mut ta).unwrap();
        b.allreduce_sum(&mut tb).unwrap();
        assert_eq!(ta.data[0], 2.0);
        assert_eq!(tb.data[0], 2.0);
    });
}

#[test]
fn dup_gives_isolated_tag_space() {
    World::run(2, |c| {
        let d = c.dup();
        if c.rank() == 0 {
            c.send(&Tensor::scalar(1.0), 1, 9);
            d.send(&Tensor::scalar(2.0), 1, 9);
        } else {
            // Same (src, tag) but different comm: no cross-matching.
            assert_eq!(d.recv(0, 9).data[0], 2.0);
            assert_eq!(c.recv(0, 9).data[0], 1.0);
        }
    });
}

#[test]
fn stats_count_traffic() {
    World::run(2, |c| {
        if c.rank() == 0 {
            c.send(&Tensor::full(&[10], 1.0), 1, 1);
            let s = c.stats();
            assert_eq!(s.sends, 1);
            assert_eq!(s.bytes_sent, 40);
        } else {
            c.recv(0, 1);
            let s = c.stats();
            assert_eq!(s.recvs, 1);
            assert_eq!(s.bytes_recv, 40);
        }
    });
}

#[test]
fn stats_count_isend_wait_pairing() {
    World::run(2, |c| {
        if c.rank() == 0 {
            let r1 = c.isend(&Tensor::full(&[10], 1.0), 1, 1);
            let r2 = c.isend(&Tensor::full(&[5], 2.0), 1, 2);
            assert_eq!(c.wait(r1), 40);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 1), "one send still posted");
            assert_eq!(s.sends, 2, "buffered isend enqueues at post time");
            assert_eq!(c.wait(r2), 20);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 2), "drained: posts == waits");
        } else {
            c.recv(0, 1);
            c.recv(0, 2);
        }
    });
}

#[test]
fn fusion_buffer_fuses_and_matches_unfused() {
    World::run(4, |c| {
        let mut a = Tensor::full(&[100], c.rank() as f32);
        let mut b = Tensor::full(&[50], 2.0 * c.rank() as f32);
        let mut cc = Tensor::full(&[200], 1.0);
        {
            let fb = FusionBuffer::new(usize::MAX, AllreduceAlgo::Ring);
            let mut grads = [&mut a, &mut b, &mut cc];
            let calls = fb.allreduce_mean(c, &mut grads).unwrap();
            assert_eq!(calls, 1, "everything fits one bucket");
        }
        assert_eq!(a.data, vec![1.5; 100]);
        assert_eq!(b.data, vec![3.0; 50]);
        assert_eq!(cc.data, vec![1.0; 200]);
    });
}

#[test]
fn fusion_buffer_respects_threshold() {
    World::run(2, |c| {
        let mut a = Tensor::full(&[100], 2.0); // 400 B
        let mut b = Tensor::full(&[100], 4.0);
        let mut d = Tensor::full(&[100], 6.0);
        let fb = FusionBuffer::new(500, AllreduceAlgo::Ring);
        let mut grads = [&mut a, &mut b, &mut d];
        let calls = fb.allreduce_mean(c, &mut grads).unwrap();
        assert_eq!(calls, 3, "400B each, 500B cap -> one bucket per tensor");
        assert_eq!(a.data[0], 2.0);
        assert_eq!(b.data[0], 4.0);
        assert_eq!(d.data[0], 6.0);
    });
}

#[test]
fn world_returns_rank_ordered_results() {
    let outs = World::run(5, |c| c.rank() * 10);
    assert_eq!(outs, vec![0, 10, 20, 30, 40]);
}

#[test]
#[should_panic(expected = "deadlock watchdog")]
fn watchdog_fires_on_missing_message() {
    World::run_with_timeout(2, std::time::Duration::from_secs(1), |c| {
        if c.rank() == 1 {
            c.recv(0, 999); // nobody sends
        }
    });
}
