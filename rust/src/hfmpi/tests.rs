//! Unit tests for the hfmpi fabric: point-to-point semantics under both
//! transports, communicator splitting, every collective algorithm, the
//! deadlock watchdog, and the fusion buffer.
//!
//! Tests that rely on buffered reordering (receiving in reverse post
//! order while the sender has already moved on) pin
//! `Transport::Buffered` explicitly — under rendezvous the same blocking
//! sends would park the sender on the first unmatched message. Rendezvous
//! twins use `isend` so multiple messages can be pending at once.

use super::*;
use crate::tensor::Tensor;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

const BOTH: [Transport; 2] = [Transport::Buffered, Transport::Rendezvous];

#[test]
fn send_recv_basic() {
    for tr in BOTH {
        World::run_with_transport(2, tr, |c| {
            assert_eq!(c.transport(), tr);
            if c.rank() == 0 {
                c.send(&Tensor::full(&[3], 7.0), 1, 42);
            } else {
                let t = c.recv(0, 42);
                assert_eq!(t.data, vec![7.0; 3]);
            }
        });
    }
}

#[test]
fn send_recv_fifo_order_per_tag() {
    // Blocking sends keep FIFO under both transports (under rendezvous
    // each send simply parks until its in-order recv).
    for tr in BOTH {
        World::run_with_transport(2, tr, |c| {
            if c.rank() == 0 {
                for i in 0..10 {
                    c.send(&Tensor::scalar(i as f32), 1, 5);
                }
            } else {
                for i in 0..10 {
                    assert_eq!(c.recv(0, 5).data[0], i as f32, "{tr:?}");
                }
            }
        });
    }
}

#[test]
fn isend_fifo_order_per_tag() {
    // Ten posts pending on one (src, tag) key at once: matching must pop
    // the per-key queue FIFO under both transports.
    for tr in BOTH {
        World::run_with_transport(2, tr, |c| {
            if c.rank() == 0 {
                let reqs: Vec<SendReq> =
                    (0..10).map(|i| c.isend(&Tensor::scalar(i as f32), 1, 5)).collect();
                for r in reqs {
                    c.wait(r);
                }
            } else {
                for i in 0..10 {
                    assert_eq!(c.recv(0, 5).data[0], i as f32, "{tr:?}");
                }
            }
        });
    }
}

#[test]
fn tags_do_not_cross_match() {
    // Reverse-order receive of two *blocking* sends relies on buffered
    // completion (under rendezvous, send(tag 100) would park forever).
    World::run_with_transport(2, Transport::Buffered, |c| {
        if c.rank() == 0 {
            c.send(&Tensor::scalar(1.0), 1, 100);
            c.send(&Tensor::scalar(2.0), 1, 200);
        } else {
            // Receive in reverse tag order: matching must be by tag.
            assert_eq!(c.recv(0, 200).data[0], 2.0);
            assert_eq!(c.recv(0, 100).data[0], 1.0);
        }
    });
}

#[test]
fn tags_do_not_cross_match_among_pending_rendezvous_sends() {
    // The rendezvous twin: both messages pending as isends, receiver
    // consumes them in reverse post order — matching is by tag, and both
    // waits then complete.
    World::run_with_transport(2, Transport::Rendezvous, |c| {
        if c.rank() == 0 {
            let r1 = c.isend(&Tensor::scalar(1.0), 1, 100);
            let r2 = c.isend(&Tensor::scalar(2.0), 1, 200);
            c.wait(r1);
            c.wait(r2);
        } else {
            assert_eq!(c.recv(0, 200).data[0], 2.0);
            assert_eq!(c.recv(0, 100).data[0], 1.0);
        }
    });
}

#[test]
fn sends_from_different_sources_do_not_cross_match() {
    World::run(3, |c| {
        match c.rank() {
            0 => c.send(&Tensor::scalar(10.0), 2, 7),
            1 => c.send(&Tensor::scalar(20.0), 2, 7),
            _ => {
                assert_eq!(c.recv(1, 7).data[0], 20.0);
                assert_eq!(c.recv(0, 7).data[0], 10.0);
            }
        }
    });
}

#[test]
fn barrier_all_sizes() {
    for tr in BOTH {
        for n in [1, 2, 3, 4, 7, 8] {
            World::run_with_transport(n, tr, |c| {
                for _ in 0..3 {
                    c.barrier();
                }
            });
        }
    }
}

#[test]
fn bcast_from_each_root() {
    for tr in BOTH {
        for n in [1, 2, 3, 5, 8] {
            for root in 0..n {
                World::run_with_transport(n, tr, move |c| {
                    let mut t = if c.rank() == root {
                        Tensor::full(&[4], 3.5)
                    } else {
                        Tensor::zeros(&[4])
                    };
                    c.bcast(&mut t, root);
                    assert_eq!(t.data, vec![3.5; 4], "n={n} root={root} rank={}", c.rank());
                });
            }
        }
    }
}

#[test]
fn allgather_rank_order() {
    for tr in BOTH {
        for n in [1, 2, 3, 6] {
            World::run_with_transport(n, tr, |c| {
                let mine = Tensor::scalar(c.rank() as f32);
                let all = c.allgather(&mine);
                let got: Vec<f32> = all.iter().map(|t| t.data[0]).collect();
                let want: Vec<f32> = (0..n).map(|r| r as f32).collect();
                assert_eq!(got, want);
            });
        }
    }
}

fn check_allreduce(n: usize, len: usize, algo: AllreduceAlgo) {
    // Every algorithm must complete (and agree) on both transports: the
    // exchange-shaped steps are written sendrecv-style for exactly this.
    for tr in BOTH {
        World::run_with_transport(n, tr, move |c| {
            let mut t = Tensor::new(
                crate::tensor::Shape::new(&[len]),
                (0..len).map(|i| (c.rank() + 1) as f32 * (i + 1) as f32).collect(),
            );
            c.allreduce_sum_with(&mut t, algo).unwrap();
            let rank_sum: f32 = (1..=n).sum::<usize>() as f32;
            for (i, v) in t.data.iter().enumerate() {
                let want = rank_sum * (i + 1) as f32;
                assert!(
                    (v - want).abs() < 1e-3,
                    "n={n} len={len} algo={algo:?} {tr:?} i={i}: {v} != {want}"
                );
            }
        });
    }
}

#[test]
fn allreduce_naive() {
    for n in [1, 2, 3, 4, 5] {
        check_allreduce(n, 17, AllreduceAlgo::Naive);
    }
}

#[test]
fn allreduce_ring() {
    // Includes len < n (empty chunks) and len not divisible by n.
    for n in [2, 3, 4, 5, 8] {
        for len in [1, 3, 64, 1000] {
            check_allreduce(n, len, AllreduceAlgo::Ring);
        }
    }
}

#[test]
fn allreduce_recursive_doubling() {
    for n in [2, 4, 8] {
        check_allreduce(n, 33, AllreduceAlgo::RecursiveDoubling);
    }
    // Non-power-of-two silently falls back to ring.
    check_allreduce(3, 33, AllreduceAlgo::RecursiveDoubling);
}

#[test]
fn allreduce_auto() {
    for n in [2, 3, 4, 6, 8] {
        check_allreduce(n, 100, AllreduceAlgo::Auto);
        check_allreduce(n, 100_000, AllreduceAlgo::Auto);
    }
}

#[test]
fn allreduce_mean_averages() {
    World::run(4, |c| {
        let mut t = Tensor::full(&[8], c.rank() as f32);
        c.allreduce_mean(&mut t).unwrap();
        assert_eq!(t.data, vec![1.5; 8]); // mean(0,1,2,3)
    });
}

#[test]
fn split_by_color_groups_and_orders() {
    // 6 ranks, color = rank % 2 -> two comms of 3 ordered by rank.
    World::run(6, |c| {
        let sub = c.split((c.rank() % 2) as i64, c.rank() as i64);
        assert_eq!(sub.size(), 3);
        assert_eq!(sub.rank(), c.rank() / 2);
        // Collectives work inside the sub-communicator.
        let mut t = Tensor::scalar(c.rank() as f32);
        sub.allreduce_sum(&mut t).unwrap();
        let want = if c.rank() % 2 == 0 { 0. + 2. + 4. } else { 1. + 3. + 5. };
        assert_eq!(t.data[0], want);
    });
}

#[test]
fn split_key_reorders_ranks() {
    World::run(4, |c| {
        // All same color; key = -rank reverses the ordering.
        let sub = c.split(0, -(c.rank() as i64));
        assert_eq!(sub.rank(), 3 - c.rank());
    });
}

#[test]
fn repeated_splits_are_independent() {
    World::run(4, |c| {
        let a = c.split((c.rank() % 2) as i64, 0);
        let b = c.split((c.rank() / 2) as i64, 0);
        let mut ta = Tensor::scalar(1.0);
        let mut tb = Tensor::scalar(1.0);
        a.allreduce_sum(&mut ta).unwrap();
        b.allreduce_sum(&mut tb).unwrap();
        assert_eq!(ta.data[0], 2.0);
        assert_eq!(tb.data[0], 2.0);
    });
}

#[test]
fn dup_gives_isolated_tag_space() {
    // Reverse-comm-order receive of blocking sends: buffered-only (see
    // tags_do_not_cross_match); the rendezvous twin below uses isend.
    World::run_with_transport(2, Transport::Buffered, |c| {
        let d = c.dup();
        if c.rank() == 0 {
            c.send(&Tensor::scalar(1.0), 1, 9);
            d.send(&Tensor::scalar(2.0), 1, 9);
        } else {
            // Same (src, tag) but different comm: no cross-matching.
            assert_eq!(d.recv(0, 9).data[0], 2.0);
            assert_eq!(c.recv(0, 9).data[0], 1.0);
        }
    });
}

#[test]
fn dup_gives_isolated_tag_space_under_rendezvous() {
    World::run_with_transport(2, Transport::Rendezvous, |c| {
        let d = c.dup();
        if c.rank() == 0 {
            let r1 = c.isend(&Tensor::scalar(1.0), 1, 9);
            let r2 = d.isend(&Tensor::scalar(2.0), 1, 9);
            c.wait(r1);
            d.wait(r2);
        } else {
            assert_eq!(d.recv(0, 9).data[0], 2.0);
            assert_eq!(c.recv(0, 9).data[0], 1.0);
        }
    });
}

#[test]
fn stats_count_traffic() {
    World::run(2, |c| {
        if c.rank() == 0 {
            c.send(&Tensor::full(&[10], 1.0), 1, 1);
            let s = c.stats();
            assert_eq!(s.sends, 1);
            assert_eq!(s.bytes_sent, 40);
        } else {
            c.recv(0, 1);
            let s = c.stats();
            assert_eq!(s.recvs, 1);
            assert_eq!(s.bytes_recv, 40);
        }
    });
}

#[test]
fn stats_count_isend_wait_pairing() {
    // Buffered accounting: isends complete (and count as sends) at post.
    World::run_with_transport(2, Transport::Buffered, |c| {
        if c.rank() == 0 {
            let r1 = c.isend(&Tensor::full(&[10], 1.0), 1, 1);
            let r2 = c.isend(&Tensor::full(&[5], 2.0), 1, 2);
            assert_eq!(c.wait(r1), 40);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 1), "one send still posted");
            assert_eq!(s.sends, 2, "buffered isend enqueues at post time");
            assert_eq!(c.wait(r2), 20);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 2), "drained: posts == waits");
        } else {
            c.recv(0, 1);
            c.recv(0, 2);
        }
    });
}

#[test]
fn stats_count_isend_wait_pairing_under_rendezvous() {
    // Rendezvous accounting: posting only counts the isend; the send (and
    // its bytes/secs) are credited at match time, inside the wait.
    World::run_with_transport(2, Transport::Rendezvous, |c| {
        if c.rank() == 0 {
            let r1 = c.isend(&Tensor::full(&[10], 1.0), 1, 1);
            let r2 = c.isend(&Tensor::full(&[5], 2.0), 1, 2);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 0));
            assert_eq!((s.sends, s.bytes_sent), (0, 0), "no send completed before the match");
            assert_eq!(c.wait(r1), 40);
            let s = c.stats();
            assert_eq!((s.sends, s.bytes_sent), (1, 40), "send credited at match time");
            assert_eq!(c.wait(r2), 20);
            let s = c.stats();
            assert_eq!((s.isends, s.waits), (2, 2), "drained: posts == waits");
            assert_eq!((s.sends, s.bytes_sent), (2, 60));
        } else {
            c.recv(0, 1);
            c.recv(0, 2);
        }
    });
}

#[test]
fn fusion_buffer_fuses_and_matches_unfused() {
    World::run(4, |c| {
        let mut a = Tensor::full(&[100], c.rank() as f32);
        let mut b = Tensor::full(&[50], 2.0 * c.rank() as f32);
        let mut cc = Tensor::full(&[200], 1.0);
        {
            let fb = FusionBuffer::new(usize::MAX, AllreduceAlgo::Ring);
            let mut grads = [&mut a, &mut b, &mut cc];
            let calls = fb.allreduce_mean(c, &mut grads).unwrap();
            assert_eq!(calls, 1, "everything fits one bucket");
        }
        assert_eq!(a.data, vec![1.5; 100]);
        assert_eq!(b.data, vec![3.0; 50]);
        assert_eq!(cc.data, vec![1.0; 200]);
    });
}

#[test]
fn fusion_buffer_respects_threshold() {
    World::run(2, |c| {
        let mut a = Tensor::full(&[100], 2.0); // 400 B
        let mut b = Tensor::full(&[100], 4.0);
        let mut d = Tensor::full(&[100], 6.0);
        let fb = FusionBuffer::new(500, AllreduceAlgo::Ring);
        let mut grads = [&mut a, &mut b, &mut d];
        let calls = fb.allreduce_mean(c, &mut grads).unwrap();
        assert_eq!(calls, 3, "400B each, 500B cap -> one bucket per tensor");
        assert_eq!(a.data[0], 2.0);
        assert_eq!(b.data[0], 4.0);
        assert_eq!(d.data[0], 6.0);
    });
}

#[test]
fn world_returns_rank_ordered_results() {
    let outs = World::run(5, |c| c.rank() * 10);
    assert_eq!(outs, vec![0, 10, 20, 30, 40]);
}

#[test]
#[should_panic(expected = "deadlock watchdog")]
fn watchdog_fires_on_missing_message() {
    World::run_with_timeout(2, Duration::from_secs(1), |c| {
        if c.rank() == 1 {
            c.recv(0, 999); // nobody sends
        }
    });
}

// ---------------------------------------------------------------------------
// Rendezvous transport semantics
// ---------------------------------------------------------------------------

#[test]
fn rendezvous_send_blocks_until_recv_is_posted() {
    World::run_with_transport(2, Transport::Rendezvous, |c| {
        if c.rank() == 0 {
            let t0 = Instant::now();
            c.send(&Tensor::scalar(1.0), 1, 7);
            assert!(
                t0.elapsed() >= Duration::from_millis(80),
                "rendezvous send returned before the matching recv was posted"
            );
        } else {
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(c.recv(0, 7).data[0], 1.0);
        }
    });
}

#[test]
fn rendezvous_wait_blocks_until_match() {
    World::run_with_transport(2, Transport::Rendezvous, |c| {
        if c.rank() == 0 {
            let req = c.isend(&Tensor::scalar(2.0), 1, 7);
            let t0 = Instant::now();
            c.wait(req);
            assert!(
                t0.elapsed() >= Duration::from_millis(80),
                "rendezvous wait returned before the matching recv was posted"
            );
        } else {
            std::thread::sleep(Duration::from_millis(100));
            assert_eq!(c.recv(0, 7).data[0], 2.0);
        }
    });
}

#[test]
fn isend_payload_is_pinned_at_post_time() {
    // The fabric pins a copy at post, so mutating the caller's buffer
    // between post and match must not leak into the delivered payload.
    for tr in BOTH {
        World::run_with_transport(2, tr, |c| {
            if c.rank() == 0 {
                let mut t = Tensor::scalar(5.0);
                let req = c.isend(&t, 1, 3);
                t.data[0] = 99.0; // caller reuses the buffer immediately
                c.wait(req);
            } else {
                // Ensure the match happens after the mutation.
                std::thread::sleep(Duration::from_millis(50));
                assert_eq!(c.recv(0, 3).data[0], 5.0, "{tr:?}");
            }
        });
    }
}

#[test]
fn facing_blocking_sends_complete_under_buffered() {
    World::run_with_transport(2, Transport::Buffered, |c| {
        let peer = 1 - c.rank();
        c.send(&Tensor::scalar(c.rank() as f32), peer, 1);
        assert_eq!(c.recv(peer, 1).data[0], peer as f32);
    });
}

#[test]
#[should_panic(expected = "deadlock watchdog")]
fn facing_blocking_sends_deadlock_under_rendezvous() {
    // The head-to-head pattern at the core of the 1F1B blocking-send
    // hazard, now reproducible on the live fabric.
    World::run_with(2, Transport::Rendezvous, Some(Duration::from_millis(300)), |c| {
        let peer = 1 - c.rank();
        c.send(&Tensor::scalar(0.0), peer, 1);
        c.recv(peer, 1);
    });
}

// ---------------------------------------------------------------------------
// Watchdog deadline regressions (timeout must not reset on wakeups)
// ---------------------------------------------------------------------------

/// Extract the panic message out of a caught rank panic.
fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
    e.downcast_ref::<String>()
        .cloned()
        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

#[test]
fn watchdog_deadline_survives_busy_traffic() {
    // Regression: the old watchdog restarted its timeout on every condvar
    // wakeup, so a starved rank in a busy world was never caught. Rank 0
    // streams unrelated messages into rank 2's mailbox (each push wakes
    // rank 2's condvar) while rank 2 blocks on a message that never
    // comes: the panic must still land at ~the configured timeout.
    let timeout = Duration::from_millis(500);
    let stop = AtomicBool::new(false);
    let elapsed = World::run_with(3, Transport::Buffered, Some(timeout), |c| match c.rank() {
        0 => {
            let t0 = Instant::now();
            while !stop.load(Ordering::Relaxed) && t0.elapsed() < 10 * timeout {
                c.send(&Tensor::scalar(0.0), 2, 1); // never received
                std::thread::sleep(Duration::from_millis(25));
            }
            0.0
        }
        2 => {
            let t0 = Instant::now();
            let r = catch_unwind(AssertUnwindSafe(|| c.recv(0, 999)));
            let secs = t0.elapsed().as_secs_f64();
            stop.store(true, Ordering::Relaxed);
            let msg = panic_msg(r.expect_err("starved recv must panic"));
            assert!(msg.contains("deadlock watchdog"), "unexpected panic: {msg}");
            secs
        }
        _ => 0.0,
    })[2];
    assert!(
        elapsed >= 0.4,
        "watchdog fired after {elapsed:.2}s, before the 0.5s deadline"
    );
    assert!(
        elapsed < 2.5,
        "watchdog took {elapsed:.2}s — the busy mailbox postponed the 0.5s deadline"
    );
}

#[test]
fn split_watchdog_deadline_survives_busy_splits() {
    // Same regression for the split wait loop: every completed split
    // anywhere on the fabric notifies the shared split condvar, so ranks
    // 1-3 churning dups on their own sub-communicator used to postpone a
    // starved rank 0 forever.
    let timeout = Duration::from_millis(500);
    let stop = AtomicBool::new(false);
    let elapsed = World::run_with(4, Transport::Buffered, Some(timeout), |c| {
        let sub = c.split(if c.rank() == 0 { 0 } else { 1 }, c.rank() as i64);
        if c.rank() == 0 {
            let t0 = Instant::now();
            // This world-level split is collective over all 4 ranks, but
            // ranks 1-3 never join it.
            let r = catch_unwind(AssertUnwindSafe(|| c.split(0, 0)));
            let secs = t0.elapsed().as_secs_f64();
            stop.store(true, Ordering::Relaxed);
            let msg = panic_msg(r.expect_err("starved split must panic"));
            assert!(msg.contains("deadlock watchdog"), "unexpected panic: {msg}");
            secs
        } else {
            let t0 = Instant::now();
            loop {
                // Vote collectively on exiting so no member enters a dup
                // the others skipped.
                let quit = stop.load(Ordering::Relaxed) || t0.elapsed() >= 10 * timeout;
                let votes = sub.allgather(&Tensor::scalar(if quit { 1.0 } else { 0.0 }));
                if votes.iter().any(|v| v.data[0] > 0.0) {
                    break;
                }
                let _ = sub.dup();
            }
            0.0
        }
    })[0];
    assert!(elapsed >= 0.4, "split watchdog fired after {elapsed:.2}s, before the deadline");
    assert!(
        elapsed < 2.5,
        "split watchdog took {elapsed:.2}s — busy splits postponed the 0.5s deadline"
    );
}

// ---------------------------------------------------------------------------
// Split-slot garbage collection
// ---------------------------------------------------------------------------

#[test]
fn split_slots_are_garbage_collected() {
    for tr in BOTH {
        World::run_with_transport(4, tr, |c| {
            let mut comms = Vec::new();
            for _ in 0..25 {
                comms.push(c.dup());
            }
            for i in 0..8 {
                let _ = c.split((c.rank() % 2) as i64, i);
            }
            // After the barrier every rank has returned from every split,
            // i.e. every slot has been read by all members and the last
            // reader removed it.
            c.barrier();
            assert_eq!(c.debug_split_slots(), 0, "completed split slots must be GC'd ({tr:?})");
        });
    }
}

// ---------------------------------------------------------------------------
// Strict environment parsing
// ---------------------------------------------------------------------------

#[test]
fn hfmpi_timeout_secs_parses_strictly() {
    // Tested at the value level: setting the real HFMPI_TIMEOUT_SECS in
    // the process environment would race the other tests in this binary,
    // all of which read it when spawning worlds.
    let err = crate::util::parse_env_value::<u64>("HFMPI_TIMEOUT_SECS", "soon")
        .unwrap_err()
        .to_string();
    assert!(err.contains("HFMPI_TIMEOUT_SECS") && err.contains("soon"), "{err}");
    assert_eq!(crate::util::parse_env_value::<u64>("HFMPI_TIMEOUT_SECS", "45").unwrap(), 45);
}

#[test]
fn transport_parses_strictly() {
    assert_eq!(Transport::parse("buffered").unwrap(), Transport::Buffered);
    assert_eq!(Transport::parse("rendezvous").unwrap(), Transport::Rendezvous);
    let err = Transport::parse("carrier-pigeon").unwrap_err().to_string();
    assert!(err.contains("carrier-pigeon") && err.contains("buffered|rendezvous"), "{err}");
    assert_eq!(Transport::default(), Transport::Buffered);
    assert_eq!(Transport::Buffered.label(), "buffered");
    assert_eq!(Transport::Rendezvous.label(), "rendezvous");
}
