//! Synthetic dataset substrate.
//!
//! The paper trains on CIFAR-10; this repo substitutes a deterministic
//! class-conditional generator (DESIGN.md substitution #4). Two properties
//! matter and are preserved:
//!
//! 1. **Learnability** — each class has a fixed random pattern; samples are
//!    pattern + Gaussian noise, so accuracy climbs with training and the
//!    Fig 14-16 correctness experiments are meaningful.
//! 2. **Determinism by index** — a sample is a pure function of
//!    (seed, index). Every rank of a model-parallel replica can materialize
//!    the same batch locally (the first partition needs `x`, the last needs
//!    the labels) without shipping data, and data-parallel shards are
//!    disjoint index ranges, exactly like a sharded CIFAR loader.

use crate::rng::Rng;
use crate::tensor::{Shape, Tensor};

/// Deterministic synthetic classification dataset.
#[derive(Clone)]
pub struct SyntheticDataset {
    pub classes: usize,
    /// Per-sample shape, e.g. [3, 32, 32] or [3072].
    pub sample_shape: Vec<usize>,
    /// Noise std relative to unit-norm patterns: higher = harder task.
    pub noise: f32,
    seed: u64,
    /// Class patterns, classes x numel.
    patterns: Vec<Vec<f32>>,
}

/// Offset separating the virtual train and test index spaces.
const TEST_OFFSET: u64 = 1 << 40;

impl SyntheticDataset {
    pub fn new(seed: u64, classes: usize, sample_shape: &[usize], noise: f32) -> Self {
        let numel: usize = sample_shape.iter().product();
        let patterns = (0..classes)
            .map(|c| {
                let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(c as u64));
                (0..numel).map(|_| rng.normal()).collect()
            })
            .collect();
        SyntheticDataset {
            classes,
            sample_shape: sample_shape.to_vec(),
            noise,
            seed,
            patterns,
        }
    }

    /// CIFAR-10-like default: 10 classes of [3,32,32], moderate noise.
    pub fn cifar_like(seed: u64) -> Self {
        Self::new(seed, 10, &[3, 32, 32], 1.0)
    }

    pub fn numel(&self) -> usize {
        self.sample_shape.iter().product()
    }

    /// The label of sample `idx` (pure function).
    pub fn label_of(&self, idx: u64) -> usize {
        // Mix so labels aren't simply periodic in idx.
        let mut r = Rng::new(self.seed ^ idx.wrapping_mul(0xD1B54A32D192ED03));
        r.below(self.classes)
    }

    /// Materialize sample `idx` into `out`.
    fn fill_sample(&self, idx: u64, out: &mut [f32]) {
        let label = self.label_of(idx);
        let mut r = Rng::new(self.seed ^ idx.wrapping_mul(0x2545F4914F6CDD1D) ^ 0xABCD);
        let pat = &self.patterns[label];
        for (o, p) in out.iter_mut().zip(pat.iter()) {
            *o = p + self.noise * r.normal();
        }
    }

    /// A training batch: (x [bs, sample_shape...], y_onehot [bs, classes],
    /// labels). Indices are `start..start+bs` in the train index space.
    pub fn batch(&self, start: u64, bs: usize) -> (Tensor, Tensor, Vec<usize>) {
        self.batch_at(start, bs, 0)
    }

    /// A held-out test batch (disjoint index space from training).
    pub fn test_batch(&self, start: u64, bs: usize) -> (Tensor, Tensor, Vec<usize>) {
        self.batch_at(start, bs, TEST_OFFSET)
    }

    fn batch_at(&self, start: u64, bs: usize, offset: u64) -> (Tensor, Tensor, Vec<usize>) {
        let numel = self.numel();
        let mut x = vec![0.0f32; bs * numel];
        let mut y = vec![0.0f32; bs * self.classes];
        let mut labels = Vec::with_capacity(bs);
        for i in 0..bs {
            let idx = offset + start + i as u64;
            self.fill_sample(idx, &mut x[i * numel..(i + 1) * numel]);
            let l = self.label_of(idx);
            labels.push(l);
            y[i * self.classes + l] = 1.0;
        }
        let mut xdims = vec![bs];
        xdims.extend_from_slice(&self.sample_shape);
        (
            Tensor::new(Shape(xdims), x),
            Tensor::new(Shape::new(&[bs, self.classes]), y),
            labels,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a = SyntheticDataset::cifar_like(7);
        let b = SyntheticDataset::cifar_like(7);
        let (xa, ya, la) = a.batch(100, 4);
        let (xb, yb, lb) = b.batch(100, 4);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        assert_eq!(la, lb);
    }

    #[test]
    fn seeds_change_data() {
        let a = SyntheticDataset::cifar_like(1);
        let b = SyntheticDataset::cifar_like(2);
        assert_ne!(a.batch(0, 2).0, b.batch(0, 2).0);
    }

    #[test]
    fn onehot_matches_labels() {
        let d = SyntheticDataset::new(3, 5, &[8], 0.5);
        let (_, y, labels) = d.batch(0, 6);
        for (i, &l) in labels.iter().enumerate() {
            for c in 0..5 {
                let want = if c == l { 1.0 } else { 0.0 };
                assert_eq!(y.data[i * 5 + c], want);
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = SyntheticDataset::cifar_like(0);
        let mut counts = [0usize; 10];
        for i in 0..10_000u64 {
            counts[d.label_of(i)] += 1;
        }
        for c in counts {
            assert!(c > 700 && c < 1300, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn train_and_test_spaces_disjoint() {
        let d = SyntheticDataset::cifar_like(0);
        let (xtr, _, _) = d.batch(0, 2);
        let (xte, _, _) = d.test_batch(0, 2);
        assert_ne!(xtr, xte);
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-pattern classification of noisy samples should beat 90%
        // at this noise level — the dataset is learnable by construction.
        let d = SyntheticDataset::new(0, 10, &[64], 0.7);
        let mut correct = 0;
        let n = 500;
        for i in 0..n {
            let (x, _, labels) = d.batch(i, 1);
            let best = (0..10)
                .max_by(|&a, &b| {
                    let da: f32 = d.patterns[a].iter().zip(&x.data).map(|(p, v)| p * v).sum();
                    let db: f32 = d.patterns[b].iter().zip(&x.data).map(|(p, v)| p * v).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == labels[0] {
                correct += 1;
            }
        }
        assert!(correct as f64 / n as f64 > 0.9, "separability {correct}/{n}");
    }

    #[test]
    fn batch_shapes() {
        let d = SyntheticDataset::cifar_like(0);
        let (x, y, l) = d.batch(0, 8);
        assert_eq!(x.shape.dims(), &[8, 3, 32, 32]);
        assert_eq!(y.shape.dims(), &[8, 10]);
        assert_eq!(l.len(), 8);
    }
}
