//! # HyPar-Flow (Rust reproduction)
//!
//! A user-transparent framework for **model-parallel**, **data-parallel** and
//! **hybrid-parallel** DNN training, reproducing *HyPar-Flow: Exploiting MPI
//! and Keras for Scalable Hybrid-Parallel DNN Training using TensorFlow*
//! (Awan et al., 2019).
//!
//! ## Architecture
//!
//! The center of the design is the **pipeline-schedule IR**
//! ([`schedule`]): a `(ModelGraph, Partitioning, num_microbatches)` triple
//! compiles into an explicit per-rank instruction program (`FwdCompute`,
//! `BwdCompute`, `Send`/`RecvActivation`, `Send`/`RecvError`, `DropStash`,
//! `AllreduceGrads`, `OptStep`) under one of four generators — `gpipe`
//! (the paper's §5.3 fill/drain), `one_f1b` (PipeDream-style
//! one-forward-one-backward with bounded in-flight microbatches),
//! `interleaved_1f1b:v=N` (Megatron-style virtual stages) or `zb_h1`
//! (zero-bubble split backward). Message ops are linearized by the paper's
//! §6.3 rank-sorted deadlock-free order (the same rule as
//! [`partition::MsgSchedule`]). Sends compile in one of two **send
//! modes**: blocking (`SendActivation`/`SendError`), which the
//! 1F1B-family schedules can only run on a *buffered* transport (facing
//! send pairs deadlock under rendezvous semantics), or **eager**
//! (`SendMode::Eager`, the engine default): each send becomes an
//! MPI_Isend-style `PostSendActivation`/`PostSendError` that never
//! blocks, completed by a `WaitSend` placed at the end of the
//! microbatch's live interval — which makes *every* generator
//! deadlock-free under both [`schedule::SendSemantics::Buffered`] and
//! [`schedule::SendSemantics::Rendezvous`], machine-checked by
//! [`schedule::Program::check`] and the conformance harness. Three
//! consumers interpret the *same* program object, so no subsystem
//! re-derives its own ordering:
//!
//! - **Trainer** ([`engine`]) — executes the instruction stream against
//!   the runtime and the communication engine; grad-layer partial-error
//!   exchange (paper Eq. 5-6) and gradient accumulation happen in
//!   instruction order, which is what makes model-parallel training
//!   *bitwise* equal to sequential execution under the same schedule.
//! - **Simulator** ([`sim`]) — replays the identical program on the
//!   calibrated cost model as a discrete-event simulation, so simulated
//!   pipeline bubbles are properties of the program the engine actually
//!   runs.
//! - **Memory model** ([`mem`]) — derives peak activation residency from
//!   the program's stash live intervals: `m` resident microbatches under
//!   GPipe, at most the pipeline depth under 1F1B (Fig 1 / Table 3
//!   trainability under either schedule).
//!
//! Supporting layers:
//!
//! - [`graph`] — Keras-equivalent model DAG (zoo: VGG-16, ResNet-v1/v2 to
//!   depth 5000), shape inference, analytic cost model.
//! - [`partition`] — the Model Generator + Load Balancer (paper §6.1):
//!   contiguous LPP partitioning, cross-edge enumeration (boundaries and
//!   skips, Fig 6), and the rendezvous deadlock checker for the §6.3
//!   message order.
//! - [`comm`] / [`hfmpi`] — the Communication Engine over an in-process
//!   MPI fabric (threads as ranks, MPI_Isend-style `post_send_*`/
//!   `wait_send` for the eager IR ops, communicator-per-partition
//!   layout, Horovod-style tensor fusion). The fabric implements both
//!   p2p transports ([`hfmpi::Transport`], env `HF_TRANSPORT`):
//!   **buffered** (MPI_Bsend — sends complete on enqueue, waits are
//!   free) and **rendezvous** (MPI_Ssend — sends complete only against
//!   the posted matching receive, so `wait_send` measures real
//!   synchronization time). Tag space for (edge x microbatch) message
//!   identities — including the worst-case *concurrently* in-flight
//!   eager sends, a static property of the compiled program — is
//!   budget-checked at `CommEngine` construction.
//! - [`runtime`] — the primitive executor. The AOT/PJRT path (HLO
//!   artifacts compiled by `python/compile/aot.py` from the JAX/Pallas
//!   primitives in `python/compile/`) is replaced in the offline build by
//!   a native CPU executor implementing the identical primitive contract;
//!   artifact names remain the interface. Its hot math lives in
//!   [`runtime::kernels`]: cache-blocked, register-tiled matmul (packed B
//!   panels, 6x16 microkernel, runtime-detected AVX2 with a portable
//!   autovectorized fallback) and row-/plane-parallel im2col/conv/dense
//!   over the scoped-thread [`runtime::pool`]. Thread count is a knob
//!   (`TrainConfig::native_threads` / `--threads` / `HF_NATIVE_THREADS`),
//!   never a result-changer: every kernel is bitwise identical to its
//!   scalar reference at any thread count (no FMA, accumulation order
//!   preserved per output element — the determinism contract the
//!   equivalence tests stand on).
//! - [`data`], [`mem`], [`sim`], [`figures`] — synthetic CIFAR-like
//!   dataset, memory model, calibrated cluster simulator, and the paper's
//!   figure/table regeneration.
//! - [`trace`] — hftrace, the observability layer: per-rank append-only
//!   buffers of typed spans keyed to the schedule IR (kind + rank/stage/
//!   microbatch/bytes tags, monotonic wall clock, logical sequence
//!   numbers). The Trainer, CommEngine and Runtime record through one
//!   [`trace::Tracer`] handle (strictly zero-cost when disabled — no clock
//!   reads, no allocation), and the simulator emits the *same* schema from
//!   its DES clock, so simulated and measured timelines cross-validate.
//!   Exports: merged multi-rank Chrome trace-event JSON
//!   ([`trace::chrome`], pid = rank, Perfetto-loadable), an aggregate
//!   report ([`trace::report`]: per-kind totals, measured bubble fraction,
//!   post→wait overlap ratio), and a structural validator
//!   ([`trace::validate`]) the conformance CI runs against real exports.
//!
//! Entry points: [`api::TrainConfig`] / [`api::fit`] (the `hf.fit()`
//! equivalent — strategy, partitions, replicas, schedule), or the
//! `hyparflow` CLI (`train`, `inspect`, `sim`, `mem`, `calibrate`;
//! `train --trace out.json` / `sim --trace out.json` capture timelines).

pub mod api;
pub mod comm;
pub mod data;
pub mod engine;
pub mod figures;
pub mod graph;
pub mod hfmpi;
pub mod mem;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod tensor;
pub mod trace;
pub mod util;
