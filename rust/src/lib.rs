//! # HyPar-Flow (Rust + JAX + Pallas reproduction)
//!
//! A user-transparent framework for **model-parallel**, **data-parallel** and
//! **hybrid-parallel** DNN training, reproducing *HyPar-Flow: Exploiting MPI
//! and Keras for Scalable Hybrid-Parallel DNN Training using TensorFlow*
//! (Awan et al., 2019).
//!
//! The stack has three layers:
//! - **L3 (this crate)** — the coordinator: model graph, partitioner
//!   (Model Generator + Load Balancer), distributed trainer with grad-layer
//!   back-propagation, communication engine over an in-process MPI fabric,
//!   and a calibrated cluster simulator for multi-node scaling studies.
//! - **L2 (python/compile/model.py)** — JAX layer primitives (fwd + VJP),
//!   AOT-lowered once to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — the Pallas matmul hot-spot kernel the
//!   L2 primitives call into.
//!
//! Python never runs at training time: the Rust hot path loads the HLO
//! artifacts via the PJRT C API (`xla` crate) and executes them directly.
//!
//! Entry points: [`api::TrainConfig`] / [`api::fit`] (the `hf.fit()`
//! equivalent), or the `hyparflow` CLI.

pub mod api;
pub mod comm;
pub mod figures;
pub mod data;
pub mod engine;
pub mod graph;
pub mod hfmpi;
pub mod mem;
pub mod partition;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;
