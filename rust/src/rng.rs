//! Small deterministic PRNG (xoshiro256**) — the build is offline, so we
//! carry our own instead of depending on `rand`. Deterministic seeding is
//! load-bearing: the synthetic dataset and weight init must be reproducible
//! across ranks so that every rank can materialize the same batch locally
//! (see `data`), and so the sequential-vs-parallel equivalence tests are
//! exact.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that small/regular seeds still produce
    /// well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> f32 mantissa precision.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
