//! Memory-consumption model (paper Fig 1 and Table 3).
//!
//! Estimates the peak training-memory footprint of a model partition:
//!
//! - **weights + gradients + optimizer state**: 3x params (SGD-momentum
//!   keeps one velocity per weight),
//! - **activations**: every node's output is stashed for backward, per
//!   microbatch *resident*. Residency is not assumed: it is derived from
//!   the schedule program's stash live intervals
//!   ([`Program::peak_resident_microbatches`]) — `m` microbatches under
//!   GPipe, at most the pipeline depth under 1F1B. This is the PipeDream
//!   observation that makes deep pipelines affordable.
//! - **workspace**: the im2col patch buffer of the largest conv (transient
//!   but counted — it dominates for large images),
//! - fixed framework overhead per process.
//!
//! `Trainable` means the partition's footprint fits the device memory —
//! exactly the paper's criterion ("fits in device memory at each training
//! step"). Model-parallelism divides the dominant activation/weight terms
//! by P, which is why ResNet-5000 trains at MP(2)/MP(4) but not
//! sequentially (Table 3).

use crate::graph::{LayerKind, ModelGraph};
use crate::partition::Partitioning;
use crate::schedule::Program;

/// Device memory budgets from the paper's Fig 1 platforms.
pub mod budgets {
    /// Pascal P100 (16 GB).
    pub const PASCAL_GB: f64 = 16.0;
    /// Volta V100 (32 GB).
    pub const VOLTA_GB: f64 = 32.0;
    /// Skylake node on Stampede2 (192 GB).
    pub const SKYLAKE_GB: f64 = 192.0;
}

/// Breakdown of one partition's estimated footprint (bytes).
#[derive(Clone, Copy, Debug, Default)]
pub struct MemEstimate {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub workspace: u64,
    pub framework: u64,
}

impl MemEstimate {
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer + self.activations
            + self.workspace + self.framework
    }

    /// Model-dependent bytes only (excludes the fixed per-process
    /// framework overhead) — what Fig 1 plots.
    pub fn model_bytes(&self) -> u64 {
        self.total() - self.framework
    }

    pub fn total_gb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Fixed per-process overhead (runtime, buffers, code). A TF 1.13 training
/// process idles between 1 and 2 GB; 2 GB reproduces the paper's measured
/// "ResNet-1k @224 needs 16.8 GB" within 1% (see `fig1` test).
const FRAMEWORK_BYTES: u64 = 2 * 1024 * 1024 * 1024;

/// Peak memory of partition `part` when training with `mb`-sized
/// microbatches and `resident_mb` microbatch stashes simultaneously live.
/// Callers with a compiled schedule should use
/// [`partition_memory_scheduled`], which derives residency from the
/// program instead of assuming it.
pub fn partition_memory(
    g: &ModelGraph,
    pt: &Partitioning,
    part: usize,
    mb: usize,
    resident_mb: usize,
) -> MemEstimate {
    let mut est = MemEstimate { framework: FRAMEWORK_BYTES, ..Default::default() };
    let mut max_patch: u64 = 0;
    for &nid in &pt.parts[part] {
        let node = &g.nodes[nid];
        let params: u64 = node.params.iter().map(|p| p.numel() as u64 * 4).sum();
        est.weights += params;
        est.gradients += params;
        est.optimizer += params;
        let act = node.out_shape.iter().product::<usize>() as u64 * 4 * mb as u64;
        est.activations += act * resident_mb as u64;
        // im2col workspace: patches are C*kh*kw per output position.
        if let LayerKind::Conv3x3 { .. } | LayerKind::ConvBnRelu { .. } = node.kind {
            let cin = g.nodes[node.inputs[0]].out_shape[0] as u64;
            let spatial = node.out_shape[1..].iter().product::<usize>() as u64;
            max_patch = max_patch.max(cin * 9 * spatial * 4 * mb as u64);
        }
    }
    est.workspace = max_patch;
    est
}

/// Peak memory of rank `rank` under a compiled schedule program. Weights,
/// gradients, optimizer state and workspace cover every stage the rank
/// owns (one for flat schedules, `v` chunks under interleaved);
/// activations come byte-accurately from the program's own stash live
/// intervals ([`Program::peak_activation_bytes`]), so the same function
/// reports GPipe's `m`-resident footprint, 1F1B's depth-bounded one, and
/// the per-chunk-weighted interleaved profile. (ZB-H1 additionally parks
/// up to `min(P - rank, m)` microbatches of parameter-shaped weight
/// gradients between `BwdInput` and `BwdWeight`; that transient is
/// bounded by `gradients * depth / m` and not counted here.) This is the
/// memory model's view of the shared schedule IR — the Trainer executes
/// it, the simulator replays it.
pub fn partition_memory_scheduled(
    g: &ModelGraph,
    pt: &Partitioning,
    rank: usize,
    mb: usize,
    program: &Program,
) -> MemEstimate {
    let mut est = MemEstimate { framework: FRAMEWORK_BYTES, ..Default::default() };
    let mut max_patch: u64 = 0;
    for stage in program.stages_of(rank) {
        for &nid in &pt.parts[stage] {
            let node = &g.nodes[nid];
            let params: u64 = node.params.iter().map(|p| p.numel() as u64 * 4).sum();
            est.weights += params;
            est.gradients += params;
            est.optimizer += params;
            if let LayerKind::Conv3x3 { .. } | LayerKind::ConvBnRelu { .. } = node.kind {
                let cin = g.nodes[node.inputs[0]].out_shape[0] as u64;
                let spatial = node.out_shape[1..].iter().product::<usize>() as u64;
                max_patch = max_patch.max(cin * 9 * spatial * 4 * mb as u64);
            }
        }
    }
    est.workspace = max_patch;
    est.activations = program.peak_activation_bytes(g, pt, rank, mb);
    est
}

/// Worst-rank peak memory under a compiled schedule program.
pub fn scheduled_memory(
    g: &ModelGraph,
    pt: &Partitioning,
    mb: usize,
    program: &Program,
) -> MemEstimate {
    (0..program.num_partitions)
        .map(|p| partition_memory_scheduled(g, pt, p, mb, program))
        .max_by_key(|e| e.total())
        .expect("at least one rank")
}

/// Whole-model memory under sequential training.
pub fn sequential_memory(g: &ModelGraph, mb: usize) -> MemEstimate {
    let pt = Partitioning::auto(g, 1).expect("single partition");
    partition_memory(g, &pt, 0, mb, 1)
}

/// Worst-partition memory under P-way model parallelism. The split is
/// **memory-balanced** (per-node activation+param bytes as the balancer
/// weight) — what an expert would hand-tune LPP to when the goal is
/// fitting an out-of-core model, as in the paper's §8 study.
pub fn mp_memory(g: &ModelGraph, partitions: usize, mb: usize) -> anyhow::Result<MemEstimate> {
    let weights: Vec<f64> = (0..g.num_nodes())
        .map(|i| {
            let c = g.node_cost(i);
            (c.activation * mb + c.params * 3) as f64 * 4.0
        })
        .collect();
    let lpp = crate::partition::auto_lpp_weighted(g, partitions, &weights)?;
    let pt = Partitioning::from_lpp(g, &lpp)?;
    Ok((0..partitions)
        .map(|p| partition_memory(g, &pt, p, mb, 1))
        .max_by_key(|e| e.total())
        .unwrap())
}

/// The paper's trainability criterion.
pub fn trainable(est: &MemEstimate, budget_gb: f64) -> bool {
    est.total_gb() <= budget_gb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn components_sum() {
        let e = MemEstimate {
            weights: 1, gradients: 2, optimizer: 3,
            activations: 4, workspace: 5, framework: 6,
        };
        assert_eq!(e.total(), 21);
    }

    #[test]
    fn resnet110_small_image_fits_everywhere() {
        let g = zoo::resnet110_v1();
        let e = sequential_memory(&g, 32);
        assert!(trainable(&e, budgets::PASCAL_GB), "{:.1} GB", e.total_gb());
    }

    #[test]
    fn deeper_needs_more() {
        let a = sequential_memory(&zoo::resnet20_v1(), 8).model_bytes();
        let b = sequential_memory(&zoo::resnet110_v1(), 8).model_bytes();
        assert!(b > 3 * a, "110-layer should dwarf 20-layer: {a} vs {b}");
    }

    #[test]
    fn fig1_resnet1k_224_exceeds_pascal() {
        // The paper's headline: ResNet-1k at 224x224, bs=1 needs ~16.8 GB —
        // more than a 16 GB Pascal.
        let g = zoo::resnet_v2(1001, &[3, 224, 224], 1000);
        let e = sequential_memory(&g, 1);
        assert!(
            !trainable(&e, budgets::PASCAL_GB),
            "ResNet-1k @224 must exceed 16 GB, got {:.1} GB",
            e.total_gb()
        );
        // and close to the paper's measured 16.8 GB.
        assert!(
            e.total_gb() > 14.0 && e.total_gb() < 20.0,
            "{:.1} GB",
            e.total_gb()
        );
    }

    #[test]
    fn mp_splits_memory() {
        let g = zoo::resnet110_v1();
        let seq = sequential_memory(&g, 32).model_bytes();
        let mp4 = mp_memory(&g, 4, 32).unwrap().model_bytes();
        // Not exactly /4 (imbalance, per-partition workspace) but the
        // model-dependent footprint must be well below sequential.
        assert!(mp4 < seq / 2, "seq={seq} mp4={mp4}");
    }

    #[test]
    fn activation_term_scales_with_microbatch() {
        let g = zoo::resnet20_v1();
        let a = sequential_memory(&g, 8).activations;
        let b = sequential_memory(&g, 16).activations;
        assert_eq!(b, a * 2);
    }

    #[test]
    fn scheduled_residency_gpipe_vs_one_f1b() {
        use crate::schedule::{Program, ScheduleKind};
        let g = zoo::resnet56_v1();
        let pt = crate::partition::Partitioning::auto(&g, 4).unwrap();
        let (mb, m) = (4usize, 16usize);
        let gp = Program::compile(&g, &pt, m, ScheduleKind::GPipe);
        let f1b = Program::compile(&g, &pt, m, ScheduleKind::OneF1B);
        for part in 0..4 {
            let a = partition_memory_scheduled(&g, &pt, part, mb, &gp);
            let b = partition_memory_scheduled(&g, &pt, part, mb, &f1b);
            // GPipe keeps all m stashes; 1F1B at most the pipeline depth.
            assert_eq!(a.activations, partition_memory(&g, &pt, part, mb, m).activations);
            assert!(
                b.activations < a.activations,
                "part {part}: 1f1b {} !< gpipe {}",
                b.activations,
                a.activations
            );
            // Weights/grads/optimizer are schedule-independent.
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.optimizer, b.optimizer);
        }
        assert!(scheduled_memory(&g, &pt, mb, &f1b).total() < scheduled_memory(&g, &pt, mb, &gp).total());
    }

    #[test]
    fn scheduled_memory_covers_all_stages_of_a_rank() {
        use crate::schedule::{Program, ScheduleKind};
        let g = zoo::resnet56_v1();
        let kind = ScheduleKind::Interleaved1F1B { v: 2 };
        let pt = kind.partitioning(&g, 2).unwrap(); // 4 stages on 2 ranks
        let prog = Program::compile(&g, &pt, 8, kind);
        for rank in 0..2 {
            let e = partition_memory_scheduled(&g, &pt, rank, 4, &prog);
            let expect_w: u64 = [rank, rank + 2]
                .iter()
                .flat_map(|&s| pt.parts[s].iter())
                .map(|&n| {
                    g.nodes[n].params.iter().map(|p| p.numel() as u64 * 4).sum::<u64>()
                })
                .sum();
            assert_eq!(e.weights, expect_w, "rank {rank} owns two chunks' params");
            assert!(e.activations > 0);
        }
    }
}
