//! The Keras-equivalent model definition layer: a DAG of typed layer nodes
//! with skip connections, shape inference, parameter specs and an analytic
//! cost model (FLOPs / bytes / params) that feeds the Load Balancer, the
//! memory estimator and the cluster simulator.
//!
//! This plays the role the *Keras model object* plays in the paper: the user
//! (or the zoo) builds a `ModelGraph` once, and the Model Generator
//! (`crate::partition`) turns it into a distributed model without any change
//! to the definition — the paper's "user-transparent" contract.
//!
//! Shapes stored per node are **per-sample** (no batch dimension); the batch
//! (microbatch) size is prepended at run time, so one graph serves any batch
//! size.

pub mod artifact;
pub mod fuse;
pub mod zoo;

use std::fmt;

/// Node index within a [`ModelGraph`]. Nodes are stored in topological
/// order by construction (the builder only lets you reference existing
/// nodes as inputs).
pub type NodeId = usize;

/// Layer types. The set mirrors what the paper's models (VGG-16,
/// ResNet-v1/v2) require, plus the fused conv+bn+relu fast-path variant.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// Graph input (the data tensor). Exactly one per graph, at node 0.
    Input,
    /// 3x3 SAME conv, `stride` in {1,2}. Params: w[K,C,3,3].
    Conv3x3 { cout: usize, stride: usize },
    /// 1x1 conv (projection shortcut / bottleneck), `stride` in {1,2}.
    Conv1x1 { cout: usize, stride: usize },
    /// Fused 3x3 conv + train-mode BN + ReLU (single artifact; perf path).
    ConvBnRelu { cout: usize, stride: usize },
    /// Train-mode batch normalization. Params: gamma[C], beta[C].
    BatchNorm,
    /// ReLU (rank-4 or rank-2 depending on input).
    Relu,
    /// Elementwise add of two branches (the ResNet skip join).
    /// Executed natively by the engine — no artifact.
    Add,
    /// 2x2 max pool, stride 2 (VGG).
    MaxPool2,
    /// Global average pool: [C,H,W] -> [C].
    GlobalAvgPool,
    /// Reshape [C,H,W] -> [C*H*W]. Free (row-major view); no artifact.
    Flatten,
    /// Fully connected. Params: w[D,M], b[M].
    Dense { units: usize },
    /// Fused dense + ReLU.
    DenseRelu { units: usize },
    /// Softmax cross-entropy head: consumes logits, produces
    /// (scalar loss, dloss/dlogits). Terminal node; labels are supplied by
    /// the engine, not modeled as a graph edge.
    SoftmaxXent,
}

impl LayerKind {
    /// Does this layer carry trainable parameters?
    pub fn has_params(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv3x3 { .. }
                | LayerKind::Conv1x1 { .. }
                | LayerKind::ConvBnRelu { .. }
                | LayerKind::BatchNorm
                | LayerKind::Dense { .. }
                | LayerKind::DenseRelu { .. }
        )
    }

    /// Is this a "weight layer" in the paper's layer-counting sense
    /// (conv/dense — what "ResNet-110 has 110 layers" counts)?
    pub fn is_weight_layer(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv3x3 { .. }
                | LayerKind::Conv1x1 { .. }
                | LayerKind::ConvBnRelu { .. }
                | LayerKind::Dense { .. }
                | LayerKind::DenseRelu { .. }
        )
    }
}

/// A trainable parameter slot of a node.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    /// Human-readable role: "w", "b", "gamma", "beta".
    pub role: &'static str,
    pub dims: Vec<usize>,
    /// Fan-in for He-normal init (0 => init to the role's default:
    /// gamma=1, beta=0, b=0).
    pub fan_in: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One node of the model graph.
#[derive(Clone, Debug)]
pub struct LayerNode {
    pub id: NodeId,
    pub kind: LayerKind,
    /// Producer nodes (1 for most layers, 2 for Add).
    pub inputs: Vec<NodeId>,
    /// Per-sample output shape (no batch dim).
    pub out_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

/// Per-node analytic costs (per sample).
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeCost {
    /// Forward FLOPs per sample. Backward is modeled as 2x forward.
    pub flops: f64,
    /// Output activation elements per sample (saved for backward).
    pub activation: usize,
    /// Trainable parameter count.
    pub params: usize,
}

/// The model: a topologically-ordered DAG with exactly one `Input` node
/// (id 0) and a `SoftmaxXent` terminal for trainable models.
#[derive(Clone)]
pub struct ModelGraph {
    pub name: String,
    /// Per-sample input shape, e.g. [3, 32, 32].
    pub input_shape: Vec<usize>,
    pub nodes: Vec<LayerNode>,
}

impl ModelGraph {
    /// Start a graph; node 0 is the input.
    pub fn new(name: &str, input_shape: &[usize]) -> Self {
        let input = LayerNode {
            id: 0,
            kind: LayerKind::Input,
            inputs: vec![],
            out_shape: input_shape.to_vec(),
            params: vec![],
        };
        ModelGraph {
            name: name.to_string(),
            input_shape: input_shape.to_vec(),
            nodes: vec![input],
        }
    }

    pub fn input(&self) -> NodeId {
        0
    }

    fn shape_of(&self, id: NodeId) -> &[usize] {
        &self.nodes[id].out_shape
    }

    fn push(&mut self, kind: LayerKind, inputs: Vec<NodeId>) -> NodeId {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "input node {i} does not exist yet");
        }
        let id = self.nodes.len();
        let (out_shape, params) = self.infer(&kind, &inputs);
        self.nodes.push(LayerNode { id, kind, inputs, out_shape, params });
        id
    }

    /// Shape inference + parameter specs for a new node.
    fn infer(&self, kind: &LayerKind, inputs: &[NodeId]) -> (Vec<usize>, Vec<ParamSpec>) {
        let in0 = inputs.first().map(|&i| self.shape_of(i).to_vec());
        match kind {
            LayerKind::Input => unreachable!("Input is created by new()"),
            LayerKind::Conv3x3 { cout, stride }
            | LayerKind::ConvBnRelu { cout, stride } => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 3, "conv expects [C,H,W], got {s:?}");
                let (c, h, w) = (s[0], s[1], s[2]);
                let (ho, wo) = (h.div_ceil(*stride), w.div_ceil(*stride));
                let mut params = vec![ParamSpec {
                    role: "w",
                    dims: vec![*cout, c, 3, 3],
                    fan_in: 9 * c,
                }];
                if matches!(kind, LayerKind::ConvBnRelu { .. }) {
                    params.push(ParamSpec { role: "gamma", dims: vec![*cout], fan_in: 0 });
                    params.push(ParamSpec { role: "beta", dims: vec![*cout], fan_in: 0 });
                }
                (vec![*cout, ho, wo], params)
            }
            LayerKind::Conv1x1 { cout, stride } => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 3, "conv expects [C,H,W], got {s:?}");
                let (c, h, w) = (s[0], s[1], s[2]);
                let (ho, wo) = (h.div_ceil(*stride), w.div_ceil(*stride));
                let params = vec![ParamSpec {
                    role: "w",
                    dims: vec![*cout, c, 1, 1],
                    fan_in: c,
                }];
                (vec![*cout, ho, wo], params)
            }
            LayerKind::BatchNorm => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 3, "bn expects [C,H,W], got {s:?}");
                let c = s[0];
                let params = vec![
                    ParamSpec { role: "gamma", dims: vec![c], fan_in: 0 },
                    ParamSpec { role: "beta", dims: vec![c], fan_in: 0 },
                ];
                (s, params)
            }
            LayerKind::Relu => (in0.unwrap(), vec![]),
            LayerKind::Add => {
                assert_eq!(inputs.len(), 2, "Add takes two inputs");
                let a = self.shape_of(inputs[0]);
                let b = self.shape_of(inputs[1]);
                assert_eq!(a, b, "Add branch shapes differ: {a:?} vs {b:?}");
                (a.to_vec(), vec![])
            }
            LayerKind::MaxPool2 => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 3);
                assert!(s[1] % 2 == 0 && s[2] % 2 == 0,
                        "maxpool2 needs even H,W, got {s:?}");
                (vec![s[0], s[1] / 2, s[2] / 2], vec![])
            }
            LayerKind::GlobalAvgPool => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 3);
                (vec![s[0]], vec![])
            }
            LayerKind::Flatten => {
                let s = in0.unwrap();
                (vec![s.iter().product()], vec![])
            }
            LayerKind::Dense { units } | LayerKind::DenseRelu { units } => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 1, "dense expects flat input, got {s:?}");
                let d = s[0];
                let params = vec![
                    ParamSpec { role: "w", dims: vec![d, *units], fan_in: d },
                    ParamSpec { role: "b", dims: vec![*units], fan_in: 0 },
                ];
                (vec![*units], params)
            }
            LayerKind::SoftmaxXent => {
                let s = in0.unwrap();
                assert_eq!(s.len(), 1, "loss expects logits [C], got {s:?}");
                // Output shape recorded as the glogits shape; the scalar loss
                // is side-channel.
                (s, vec![])
            }
        }
    }

    // ---- builder methods (the Keras-like API) ----

    pub fn conv3x3(&mut self, x: NodeId, cout: usize, stride: usize) -> NodeId {
        self.push(LayerKind::Conv3x3 { cout, stride }, vec![x])
    }

    pub fn conv1x1(&mut self, x: NodeId, cout: usize, stride: usize) -> NodeId {
        self.push(LayerKind::Conv1x1 { cout, stride }, vec![x])
    }

    pub fn conv_bn_relu(&mut self, x: NodeId, cout: usize, stride: usize) -> NodeId {
        self.push(LayerKind::ConvBnRelu { cout, stride }, vec![x])
    }

    pub fn batchnorm(&mut self, x: NodeId) -> NodeId {
        self.push(LayerKind::BatchNorm, vec![x])
    }

    pub fn relu(&mut self, x: NodeId) -> NodeId {
        self.push(LayerKind::Relu, vec![x])
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.push(LayerKind::Add, vec![a, b])
    }

    pub fn maxpool2(&mut self, x: NodeId) -> NodeId {
        self.push(LayerKind::MaxPool2, vec![x])
    }

    pub fn gap(&mut self, x: NodeId) -> NodeId {
        self.push(LayerKind::GlobalAvgPool, vec![x])
    }

    pub fn flatten(&mut self, x: NodeId) -> NodeId {
        self.push(LayerKind::Flatten, vec![x])
    }

    pub fn dense(&mut self, x: NodeId, units: usize) -> NodeId {
        self.push(LayerKind::Dense { units }, vec![x])
    }

    pub fn dense_relu(&mut self, x: NodeId, units: usize) -> NodeId {
        self.push(LayerKind::DenseRelu { units }, vec![x])
    }

    pub fn loss(&mut self, logits: NodeId) -> NodeId {
        self.push(LayerKind::SoftmaxXent, vec![logits])
    }

    // ---- queries ----

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Paper-style layer count (conv + dense weight layers).
    pub fn num_weight_layers(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind.is_weight_layer()).count()
    }

    pub fn num_params(&self) -> usize {
        self.nodes
            .iter()
            .flat_map(|n| n.params.iter())
            .map(|p| p.numel())
            .sum()
    }

    /// Ids of nodes that consume `id`'s output.
    pub fn consumers(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&id))
            .map(|n| n.id)
            .collect()
    }

    /// Terminal (loss) node, if present.
    pub fn loss_node(&self) -> Option<NodeId> {
        self.nodes
            .iter()
            .rev()
            .find(|n| matches!(n.kind, LayerKind::SoftmaxXent))
            .map(|n| n.id)
    }

    /// Validate DAG invariants (used by tests and the partitioner).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.nodes.is_empty(), "empty graph");
        anyhow::ensure!(
            matches!(self.nodes[0].kind, LayerKind::Input),
            "node 0 must be Input"
        );
        for n in &self.nodes {
            for &i in &n.inputs {
                anyhow::ensure!(i < n.id, "node {} has non-topological input {i}", n.id);
            }
            let want_inputs = match n.kind {
                LayerKind::Input => 0,
                LayerKind::Add => 2,
                _ => 1,
            };
            anyhow::ensure!(
                n.inputs.len() == want_inputs,
                "node {} ({:?}) expects {} inputs, has {}",
                n.id, n.kind, want_inputs, n.inputs.len()
            );
        }
        // Every non-terminal node must be consumed (no dangling branches).
        for n in &self.nodes {
            if Some(n.id) != self.loss_node() && n.id != self.nodes.len() - 1 {
                anyhow::ensure!(
                    !self.consumers(n.id).is_empty(),
                    "node {} ({:?}) has no consumers",
                    n.id, n.kind
                );
            }
        }
        Ok(())
    }

    /// Analytic per-sample cost of one node.
    pub fn node_cost(&self, id: NodeId) -> NodeCost {
        let n = &self.nodes[id];
        let out: usize = n.out_shape.iter().product();
        let params: usize = n.params.iter().map(|p| p.numel()).sum();
        let flops = match &n.kind {
            LayerKind::Input => 0.0,
            LayerKind::Conv3x3 { cout, .. } | LayerKind::ConvBnRelu { cout, .. } => {
                let cin = self.shape_of(n.inputs[0])[0];
                let spatial: usize = n.out_shape[1..].iter().product();
                let conv = 2.0 * (*cout as f64) * (cin as f64) * 9.0 * spatial as f64;
                if matches!(n.kind, LayerKind::ConvBnRelu { .. }) {
                    conv + 10.0 * out as f64
                } else {
                    conv
                }
            }
            LayerKind::Conv1x1 { cout, .. } => {
                let cin = self.shape_of(n.inputs[0])[0];
                let spatial: usize = n.out_shape[1..].iter().product();
                2.0 * (*cout as f64) * (cin as f64) * spatial as f64
            }
            LayerKind::BatchNorm => 8.0 * out as f64,
            LayerKind::Relu | LayerKind::Add => out as f64,
            LayerKind::MaxPool2 => 4.0 * out as f64,
            LayerKind::GlobalAvgPool => {
                let s = self.shape_of(n.inputs[0]);
                (s.iter().product::<usize>()) as f64
            }
            LayerKind::Flatten => 0.0,
            LayerKind::Dense { units } | LayerKind::DenseRelu { units } => {
                let d = self.shape_of(n.inputs[0])[0];
                2.0 * d as f64 * *units as f64
            }
            LayerKind::SoftmaxXent => 5.0 * out as f64,
        };
        NodeCost { flops, activation: out, params }
    }

    /// Total forward FLOPs per sample.
    pub fn total_flops(&self) -> f64 {
        (0..self.nodes.len()).map(|i| self.node_cost(i).flops).sum()
    }
}

impl fmt::Debug for ModelGraph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "ModelGraph '{}': {} nodes, {} weight layers, {} params",
            self.name,
            self.num_nodes(),
            self.num_weight_layers(),
            self.num_params()
        )?;
        for n in &self.nodes {
            writeln!(
                f,
                "  [{:4}] {:?} <- {:?} -> {:?}",
                n.id, n.kind, n.inputs, n.out_shape
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelGraph {
        let mut g = ModelGraph::new("tiny", &[3, 8, 8]);
        let x = g.input();
        let c = g.conv3x3(x, 4, 1);
        let b = g.batchnorm(c);
        let r = g.relu(b);
        let p = g.gap(r);
        let d = g.dense(p, 10);
        g.loss(d);
        g
    }

    #[test]
    fn shapes_infer() {
        let g = tiny();
        assert_eq!(g.nodes[1].out_shape, vec![4, 8, 8]);
        assert_eq!(g.nodes[4].out_shape, vec![4]);
        assert_eq!(g.nodes[5].out_shape, vec![10]);
        g.validate().unwrap();
    }

    #[test]
    fn strided_conv_halves_spatial() {
        let mut g = ModelGraph::new("s", &[16, 32, 32]);
        let x = g.input();
        let c = g.conv3x3(x, 32, 2);
        assert_eq!(g.nodes[c].out_shape, vec![32, 16, 16]);
        let c2 = g.conv1x1(c, 64, 2);
        assert_eq!(g.nodes[c2].out_shape, vec![64, 8, 8]);
    }

    #[test]
    fn add_requires_matching_shapes() {
        let mut g = ModelGraph::new("a", &[3, 8, 8]);
        let x = g.input();
        let a = g.conv3x3(x, 4, 1);
        let b = g.conv3x3(x, 4, 1);
        let s = g.add(a, b);
        assert_eq!(g.nodes[s].out_shape, vec![4, 8, 8]);
    }

    #[test]
    #[should_panic(expected = "branch shapes differ")]
    fn add_mismatched_panics() {
        let mut g = ModelGraph::new("a", &[3, 8, 8]);
        let x = g.input();
        let a = g.conv3x3(x, 4, 1);
        let b = g.conv3x3(x, 8, 1);
        g.add(a, b);
    }

    #[test]
    fn param_counts() {
        let g = tiny();
        // conv 4*3*3*3 + bn 2*4 + dense 4*10+10
        assert_eq!(g.num_params(), 108 + 8 + 50);
        assert_eq!(g.num_weight_layers(), 2);
    }

    #[test]
    fn consumers_and_loss_node() {
        let g = tiny();
        assert_eq!(g.consumers(1), vec![2]);
        assert_eq!(g.loss_node(), Some(6));
    }

    #[test]
    fn flops_scale_with_channels() {
        let mut g = ModelGraph::new("f", &[16, 32, 32]);
        let x = g.input();
        let a = g.conv3x3(x, 16, 1);
        let b = g.conv3x3(a, 32, 1);
        assert!(g.node_cost(b).flops > g.node_cost(a).flops * 1.9);
    }

    #[test]
    fn flatten_is_free_and_correct() {
        let mut g = ModelGraph::new("fl", &[4, 2, 2]);
        let x = g.input();
        let f = g.flatten(x);
        assert_eq!(g.nodes[f].out_shape, vec![16]);
        assert_eq!(g.node_cost(f).flops, 0.0);
    }
}
