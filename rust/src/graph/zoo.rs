//! The model zoo: the paper's evaluation models (VGG-16, ResNet-v1,
//! ResNet-v2 at depths 110 / 1001 / 5000) plus MLPs for tests and the
//! ~100M-parameter end-to-end example.
//!
//! Architectures follow the Keras reference the paper trains against
//! (keras.io cifar10_resnet, the paper's accuracy baseline):
//! - ResNet-v1 (depth = 6n+2): conv-bn-relu stem; 3 stages of n basic
//!   blocks (conv-bn-relu, conv-bn, add, relu); projection (1x1 conv)
//!   shortcut on stage transitions; GAP + dense softmax head.
//! - ResNet-v2 (depth = 9n+2): conv stem; 3 stages of n bottleneck blocks
//!   (bn-relu-1x1, bn-relu-3x3, bn-relu-1x1x4); BN-relu epilogue; GAP +
//!   dense head.

use super::{ModelGraph, NodeId};

/// Plain MLP: dense_relu hidden layers + linear head + loss.
pub fn mlp(input_dim: usize, hidden: &[usize], classes: usize) -> ModelGraph {
    let mut g = ModelGraph::new("mlp", &[input_dim]);
    let mut x = g.input();
    for &h in hidden {
        x = g.dense_relu(x, h);
    }
    let logits = g.dense(x, classes);
    g.loss(logits);
    g
}

/// The end-to-end example model: ~100M parameters (3072 -> 6x4096 -> 10).
/// 3072*4096 + 5*4096^2 + 4096*10 + biases = 96.5M.
pub fn wide_mlp_100m() -> ModelGraph {
    let mut g = mlp(3072, &[4096, 4096, 4096, 4096, 4096, 4096], 10);
    g.name = "wide_mlp_100m".into();
    g
}

/// VGG-16 (13 conv + 3 dense = 16 weight layers, the paper's Fig 7/11/14
/// model), adapted to the input resolution: 32x32 CIFAR input leaves a 1x1
/// spatial map after the five pools.
pub fn vgg16(input: &[usize; 3], classes: usize) -> ModelGraph {
    let mut g = ModelGraph::new("vgg16", input);
    let mut x = g.input();
    let plan: &[&[usize]] = &[&[64, 64], &[128, 128], &[256, 256, 256],
                              &[512, 512, 512], &[512, 512, 512]];
    for stage in plan {
        for &c in *stage {
            let c1 = g.conv3x3(x, c, 1);
            x = g.relu(c1);
        }
        x = g.maxpool2(x);
    }
    x = g.flatten(x);
    x = g.dense_relu(x, 512);
    x = g.dense_relu(x, 512);
    let logits = g.dense(x, classes);
    g.loss(logits);
    g
}

/// One ResNet-v1 basic block.
fn v1_block(g: &mut ModelGraph, x: NodeId, cout: usize, stride: usize,
            project: bool) -> NodeId {
    let c1 = g.conv3x3(x, cout, stride);
    let b1 = g.batchnorm(c1);
    let r1 = g.relu(b1);
    let c2 = g.conv3x3(r1, cout, 1);
    let b2 = g.batchnorm(c2);
    let shortcut = if project { g.conv1x1(x, cout, stride) } else { x };
    let s = g.add(b2, shortcut);
    g.relu(s)
}

/// ResNet-v1 for 3-channel square inputs; depth = 6n+2.
pub fn resnet_v1(depth: usize, input: &[usize; 3], classes: usize) -> ModelGraph {
    assert!(depth >= 8 && (depth - 2) % 6 == 0,
            "v1 depth must be 6n+2, got {depth}");
    let n = (depth - 2) / 6;
    let mut g = ModelGraph::new(&format!("resnet{depth}_v1"), input);
    let mut x = g.input();
    let c = g.conv3x3(x, 16, 1);
    let b = g.batchnorm(c);
    x = g.relu(b);
    for (stage, &cout) in [16usize, 32, 64].iter().enumerate() {
        for block in 0..n {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let project = stage > 0 && block == 0;
            x = v1_block(&mut g, x, cout, stride, project);
        }
    }
    let p = g.gap(x);
    let logits = g.dense(p, classes);
    g.loss(logits);
    g
}

/// One ResNet-v2 bottleneck block (pre-activation):
/// bn-relu-conv1x1(f) . bn-relu-conv3x3(f) . bn-relu-conv1x1(fout), with a
/// 1x1 projection shortcut from the block input on stage transitions
/// (matching the Keras cifar10_resnet v2 reference the paper trains).
fn v2_block(g: &mut ModelGraph, x: NodeId, f: usize, fout: usize,
            stride: usize, project: bool) -> NodeId {
    let b1 = g.batchnorm(x);
    let r1 = g.relu(b1);
    let c1 = g.conv1x1(r1, f, stride);
    let b2 = g.batchnorm(c1);
    let r2 = g.relu(b2);
    let c2 = g.conv3x3(r2, f, 1);
    let b3 = g.batchnorm(c2);
    let r3 = g.relu(b3);
    let c3 = g.conv1x1(r3, fout, 1);
    let shortcut = if project { g.conv1x1(x, fout, stride) } else { x };
    g.add(c3, shortcut)
}

/// ResNet-v2 (pre-activation bottleneck); depth = 9n+2. Bottleneck widths
/// per stage are (16, 64, 128) with outputs (64, 128, 256), following the
/// Keras reference — this is what yields the paper's "ResNet-1001 has
/// ~30 million parameters" (He et al.'s original v2 uses narrower
/// bottlenecks and lands at 10.2M).
pub fn resnet_v2(depth: usize, input: &[usize; 3], classes: usize) -> ModelGraph {
    assert!(depth >= 11 && (depth - 2) % 9 == 0,
            "v2 depth must be 9n+2, got {depth}");
    let n = (depth - 2) / 9;
    let mut g = ModelGraph::new(&format!("resnet{depth}_v2"), input);
    let x0 = g.input();
    let mut x = g.conv3x3(x0, 16, 1);
    let mut f_in = 16usize;
    for stage in 0..3 {
        let fout = if stage == 0 { f_in * 4 } else { f_in * 2 };
        for block in 0..n {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let project = block == 0;
            x = v2_block(&mut g, x, f_in, fout, stride, project);
        }
        f_in = fout;
    }
    let b = g.batchnorm(x);
    let r = g.relu(b);
    let p = g.gap(r);
    let logits = g.dense(p, classes);
    g.loss(logits);
    g
}

pub fn resnet20_v1() -> ModelGraph {
    resnet_v1(20, &[3, 32, 32], 10)
}

pub fn resnet56_v1() -> ModelGraph {
    resnet_v1(56, &[3, 32, 32], 10)
}

/// The paper's Fig 8/9/15 model.
pub fn resnet110_v1() -> ModelGraph {
    resnet_v1(110, &[3, 32, 32], 10)
}

pub fn resnet164_v2() -> ModelGraph {
    resnet_v2(164, &[3, 32, 32], 10)
}

/// The paper's Fig 10/12/13/16 model (9*111+2 = 1001).
pub fn resnet1001_v2() -> ModelGraph {
    resnet_v2(1001, &[3, 32, 32], 10)
}

/// The paper's §8 next-generation model: closest 9n+2 configuration to
/// 5,000 layers (9*555+2 = 4997), at the paper's 331x331 image size.
pub fn resnet5000() -> ModelGraph {
    let mut g = resnet_v2(4997, &[3, 332, 332], 10);
    g.name = "resnet5000".into();
    g
}

/// Resolve a model by CLI name. `input` overrides the default input shape
/// where the architecture allows it.
pub fn by_name(name: &str) -> anyhow::Result<ModelGraph> {
    Ok(match name {
        "mlp" => mlp(3072, &[512, 512], 10),
        "wide_mlp_100m" => wide_mlp_100m(),
        "vgg16" => vgg16(&[3, 32, 32], 10),
        "resnet20" => resnet20_v1(),
        "resnet56" => resnet56_v1(),
        "resnet110" => resnet110_v1(),
        "resnet164" => resnet164_v2(),
        "resnet1001" => resnet1001_v2(),
        "resnet5000" => resnet5000(),
        other => anyhow::bail!(
            "unknown model '{other}' (known: mlp, wide_mlp_100m, vgg16, \
             resnet20, resnet56, resnet110, resnet164, resnet1001, resnet5000)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_shapes() {
        let g = mlp(10, &[8, 6], 4);
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 3);
        assert_eq!(g.num_params(), 10 * 8 + 8 + 8 * 6 + 6 + 6 * 4 + 4);
    }

    #[test]
    fn wide_mlp_is_about_100m() {
        let g = wide_mlp_100m();
        let p = g.num_params();
        assert!(p > 90_000_000 && p < 110_000_000, "params={p}");
    }

    #[test]
    fn vgg16_has_16_weight_layers() {
        let g = vgg16(&[3, 32, 32], 10);
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 16);
        // 32 -> 1 spatial after 5 pools; flatten gives 512.
        let flat = g.nodes.iter().find(|n| matches!(n.kind, super::super::LayerKind::Flatten)).unwrap();
        assert_eq!(flat.out_shape, vec![512]);
        // VGG-16 CIFAR params ~15M (conv 14.7M + heads).
        let p = g.num_params();
        assert!(p > 14_000_000 && p < 16_000_000, "params={p}");
    }

    #[test]
    fn resnet_v1_depth_counting() {
        // depth = weight layers when counting conv+dense MINUS projection
        // shortcuts: the nominal "110 layers" counts 109 convs + 1 dense;
        // our graph additionally has 2 projection convs.
        let g = resnet110_v1();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 110 + 2);
        // ResNet-110 v1 CIFAR is ~1.7M params.
        let p = g.num_params();
        assert!(p > 1_500_000 && p < 2_000_000, "params={p}");
    }

    #[test]
    fn resnet20_structure() {
        let g = resnet20_v1();
        g.validate().unwrap();
        assert_eq!(g.num_weight_layers(), 20 + 2);
        let p = g.num_params();
        assert!(p > 250_000 && p < 300_000, "params={p}"); // ~0.27M
    }

    #[test]
    fn resnet_v1_rejects_bad_depth() {
        let r = std::panic::catch_unwind(|| resnet_v1(21, &[3, 32, 32], 10));
        assert!(r.is_err());
    }

    #[test]
    fn resnet_v2_164_shapes() {
        let g = resnet164_v2();
        g.validate().unwrap();
        // 164 = 9*18+2: 18 blocks/stage, 3 convs/block = 162 convs + stem +
        // dense; plus 3 projection convs.
        assert_eq!(g.num_weight_layers(), 164 + 3);
        let p = g.num_params();
        assert!(p > 2_000_000 && p < 6_000_000, "params={p}");
    }

    #[test]
    fn resnet1001_params_match_paper() {
        let g = resnet1001_v2();
        // The paper says "approximately 30 million parameters" (Keras-style
        // wide bottlenecks; He et al.'s narrow variant would be 10.2M).
        let p = g.num_params();
        assert!(p > 25_000_000 && p < 33_000_000, "params={p}");
        assert_eq!(g.num_weight_layers(), 1001 + 3);
    }

    #[test]
    fn resnet5000_builds() {
        let g = resnet5000();
        assert!(g.num_weight_layers() >= 4997);
        assert_eq!(g.input_shape, vec![3, 332, 332]);
    }

    #[test]
    fn stage_transitions_downsample() {
        let g = resnet20_v1();
        // Final pre-GAP activation must be [64, 8, 8].
        let gap = g.nodes.iter().find(|n| matches!(n.kind, super::super::LayerKind::GlobalAvgPool)).unwrap();
        assert_eq!(g.nodes[gap.inputs[0]].out_shape, vec![64, 8, 8]);
    }

    #[test]
    fn by_name_resolves_all() {
        for n in ["mlp", "vgg16", "resnet20", "resnet56", "resnet110", "resnet164"] {
            by_name(n).unwrap().validate().unwrap();
        }
        assert!(by_name("nope").is_err());
    }
}
