//! Mapping from graph nodes to AOT artifact names + registry lines.
//!
//! This is the single source of truth for the node -> primitive-instance
//! naming contract shared with `python/compile/model.py` (`instance_name` /
//! `PARAM_ORDER`): `hyparflow inspect --emit-registry` uses it to generate
//! the registry the Python AOT step compiles, and the engine uses it to look
//! up executables at run time. A mismatch shows up as a missing-artifact
//! error naming both sides.

use super::{LayerKind, ModelGraph, NodeId};

/// Artifact names for one node at a given microbatch size.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeArtifact {
    /// `<base>.fwd` artifact name.
    pub fwd: String,
    /// `<base>.bwd` artifact name (None for softmaxxent: its fwd already
    /// returns (loss, glogits)).
    pub bwd: Option<String>,
    /// The registry line that makes the Python side export this instance.
    pub registry_line: String,
}

/// Returns `None` for nodes executed natively by the engine
/// (Input / Add / Flatten).
pub fn node_artifact(g: &ModelGraph, id: NodeId, mb: usize) -> Option<NodeArtifact> {
    let node = &g.nodes[id];
    let in_shape = node.inputs.first().map(|&i| g.nodes[i].out_shape.clone());
    let (prim, params): (&str, Vec<(char, usize)>) = match &node.kind {
        LayerKind::Input | LayerKind::Add | LayerKind::Flatten => return None,
        LayerKind::Conv3x3 { cout, stride } => {
            let s = in_shape.unwrap();
            ("conv3x3", vec![('n', mb), ('c', s[0]), ('k', *cout),
                             ('h', s[1]), ('w', s[2]), ('s', *stride)])
        }
        LayerKind::Conv1x1 { cout, stride } => {
            let s = in_shape.unwrap();
            ("conv1x1", vec![('n', mb), ('c', s[0]), ('k', *cout),
                             ('h', s[1]), ('w', s[2]), ('s', *stride)])
        }
        LayerKind::ConvBnRelu { cout, stride } => {
            let s = in_shape.unwrap();
            ("convbnrelu", vec![('n', mb), ('c', s[0]), ('k', *cout),
                                ('h', s[1]), ('w', s[2]), ('s', *stride)])
        }
        LayerKind::BatchNorm => {
            let s = in_shape.unwrap();
            ("bn", vec![('n', mb), ('c', s[0]), ('h', s[1]), ('w', s[2])])
        }
        LayerKind::Relu => {
            let s = in_shape.unwrap();
            match s.len() {
                3 => ("relu4", vec![('n', mb), ('c', s[0]), ('h', s[1]), ('w', s[2])]),
                1 => ("relu2", vec![('n', mb), ('d', s[0])]),
                _ => panic!("relu on rank-{} input", s.len()),
            }
        }
        LayerKind::MaxPool2 => {
            let s = in_shape.unwrap();
            ("maxpool2", vec![('n', mb), ('c', s[0]), ('h', s[1]), ('w', s[2])])
        }
        LayerKind::GlobalAvgPool => {
            let s = in_shape.unwrap();
            ("gap", vec![('n', mb), ('c', s[0]), ('h', s[1]), ('w', s[2])])
        }
        LayerKind::Dense { units } => {
            let s = in_shape.unwrap();
            ("dense", vec![('n', mb), ('d', s[0]), ('m', *units)])
        }
        LayerKind::DenseRelu { units } => {
            let s = in_shape.unwrap();
            ("denserelu", vec![('n', mb), ('d', s[0]), ('m', *units)])
        }
        LayerKind::SoftmaxXent => {
            let s = in_shape.unwrap();
            ("softmaxxent", vec![('n', mb), ('c', s[0])])
        }
    };
    let base = format!(
        "{prim}{}",
        params.iter().map(|(k, v)| format!("_{k}{v}")).collect::<String>()
    );
    let registry_line = format!(
        "{prim} {}",
        params.iter().map(|(_, v)| v.to_string()).collect::<Vec<_>>().join(" ")
    );
    let bwd = if prim == "softmaxxent" { None } else { Some(format!("{base}.bwd")) };
    Some(NodeArtifact { fwd: format!("{base}.fwd"), bwd, registry_line })
}

/// All registry lines needed to run `g` at microbatch `mb` (deduplicated,
/// deterministic order).
pub fn registry_lines(g: &ModelGraph, mb: usize) -> Vec<String> {
    let mut seen = std::collections::BTreeSet::new();
    for id in 0..g.num_nodes() {
        if let Some(a) = node_artifact(g, id, mb) {
            seen.insert(a.registry_line);
        }
    }
    seen.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn conv_names_match_python_instance_name() {
        let mut g = ModelGraph::new("t", &[16, 32, 32]);
        let x = g.input();
        let c = g.conv3x3(x, 32, 2);
        let a = node_artifact(&g, c, 8).unwrap();
        assert_eq!(a.fwd, "conv3x3_n8_c16_k32_h32_w32_s2.fwd");
        assert_eq!(a.bwd.as_deref(), Some("conv3x3_n8_c16_k32_h32_w32_s2.bwd"));
        assert_eq!(a.registry_line, "conv3x3 8 16 32 32 32 2");
    }

    #[test]
    fn native_nodes_have_no_artifact() {
        let mut g = ModelGraph::new("t", &[4, 8, 8]);
        let x = g.input();
        let a = g.conv3x3(x, 4, 1);
        let b = g.conv3x3(x, 4, 1);
        let s = g.add(a, b);
        let f = g.flatten(s);
        assert!(node_artifact(&g, x, 2).is_none());
        assert!(node_artifact(&g, s, 2).is_none());
        assert!(node_artifact(&g, f, 2).is_none());
    }

    #[test]
    fn loss_has_no_bwd() {
        let g = zoo::mlp(4, &[], 3);
        let loss = g.loss_node().unwrap();
        let a = node_artifact(&g, loss, 2).unwrap();
        assert_eq!(a.fwd, "softmaxxent_n2_c3.fwd");
        assert!(a.bwd.is_none());
    }

    #[test]
    fn registry_lines_dedupe() {
        // resnet20 has many identical 16-ch conv3x3 blocks -> few lines.
        let g = zoo::resnet20_v1();
        let lines = registry_lines(&g, 8);
        assert!(lines.len() < 30, "got {} lines", lines.len());
        assert!(lines.iter().any(|l| l == "conv3x3 8 16 16 32 32 1"));
        assert!(lines.iter().any(|l| l == "softmaxxent 8 10"));
    }

    #[test]
    fn relu_rank_dispatch() {
        let mut g = ModelGraph::new("t", &[4, 8, 8]);
        let x = g.input();
        let r4 = g.relu(x);
        let f = g.flatten(r4);
        let r2 = g.relu(f);
        assert!(node_artifact(&g, r4, 2).unwrap().fwd.starts_with("relu4"));
        assert!(node_artifact(&g, r2, 2).unwrap().fwd.starts_with("relu2"));
    }
}
