//! Graph rewrite: fuse `Conv3x3 -> BatchNorm -> Relu` chains into single
//! [`LayerKind::ConvBnRelu`] nodes.
//!
//! This is the L2/L3 fusion lever of the performance pass (DESIGN.md
//! §Perf): the fused primitive is one AOT artifact — one PJRT launch and
//! one HBM round trip instead of three — and XLA fuses the BN/ReLU
//! epilogue into the conv's im2col matmul consumer. Semantically identical
//! to the unfused chain (same math, same parameters), so the equivalence
//! suite can compare fused vs unfused training directly.
//!
//! Only chains where the conv and bn outputs have no other consumers are
//! fused (skip connections tapping the intermediate keep it unfused).

use super::{LayerKind, LayerNode, ModelGraph, NodeId};

/// Returns a rewritten copy of `g` with every fusable conv-bn-relu chain
/// collapsed, plus the number of fused chains.
pub fn fuse_conv_bn_relu(g: &ModelGraph) -> (ModelGraph, usize) {
    let n = g.num_nodes();
    // consumers count per node
    let mut fanout = vec![0usize; n];
    for node in &g.nodes {
        for &i in &node.inputs {
            fanout[i] += 1;
        }
    }
    // Identify chains: conv -> bn -> relu with single-fanout conv and bn.
    // Map: relu node id -> (conv id, bn id).
    let mut chain_of_relu: Vec<Option<(NodeId, NodeId)>> = vec![None; n];
    let mut absorbed = vec![false; n];
    for node in &g.nodes {
        if !matches!(node.kind, LayerKind::Relu) {
            continue;
        }
        let bn = node.inputs[0];
        if !matches!(g.nodes[bn].kind, LayerKind::BatchNorm) || fanout[bn] != 1 {
            continue;
        }
        let conv = g.nodes[bn].inputs[0];
        if !matches!(g.nodes[conv].kind, LayerKind::Conv3x3 { .. }) || fanout[conv] != 1 {
            continue;
        }
        chain_of_relu[node.id] = Some((conv, bn));
        absorbed[conv] = true;
        absorbed[bn] = true;
    }

    // Rebuild with absorbed nodes dropped; relu nodes of a chain become
    // the fused node (keeping the relu's position preserves topology).
    let mut remap = vec![usize::MAX; n];
    let mut out = ModelGraph::new(&format!("{}_fused", g.name), &g.input_shape);
    out.nodes.clear();
    let mut fused = 0usize;
    for node in &g.nodes {
        if absorbed[node.id] {
            continue;
        }
        let new_id = out.nodes.len();
        remap[node.id] = new_id;
        let new_node = if let Some((conv, bn)) = chain_of_relu[node.id] {
            fused += 1;
            let (cout, stride) = match g.nodes[conv].kind {
                LayerKind::Conv3x3 { cout, stride } => (cout, stride),
                _ => unreachable!(),
            };
            let x = remap[g.nodes[conv].inputs[0]];
            debug_assert_ne!(x, usize::MAX, "input remapped before use");
            let mut params = g.nodes[conv].params.clone();
            params.extend(g.nodes[bn].params.clone());
            LayerNode {
                id: new_id,
                kind: LayerKind::ConvBnRelu { cout, stride },
                inputs: vec![x],
                out_shape: node.out_shape.clone(),
                params,
            }
        } else {
            LayerNode {
                id: new_id,
                kind: node.kind.clone(),
                inputs: node.inputs.iter().map(|&i| remap[i]).collect(),
                out_shape: node.out_shape.clone(),
                params: node.params.clone(),
            }
        };
        out.nodes.push(new_node);
    }
    (out, fused)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn fuses_v1_block_bodies() {
        let g = zoo::resnet20_v1();
        let (f, fused) = fuse_conv_bn_relu(&g);
        f.validate().unwrap();
        // v1: stem conv-bn-relu + first conv-bn-relu of each of 9 blocks
        // fuse; each block's second conv-bn feeds Add (bn fanout 1 but no
        // relu directly after) so it stays unfused.
        assert_eq!(fused, 10, "stem + 9 block-first chains");
        assert!(f.num_nodes() < g.num_nodes());
        // Parameters preserved exactly.
        assert_eq!(f.num_params(), g.num_params());
    }

    #[test]
    fn skip_tapped_intermediates_stay_unfused() {
        let mut g = crate::graph::ModelGraph::new("t", &[3, 8, 8]);
        let x = g.input();
        let c = g.conv3x3(x, 4, 1);
        let b = g.batchnorm(c);
        let r = g.relu(b);
        // A second consumer of the conv output blocks fusion.
        let side = g.conv3x3(c, 4, 1);
        let s = g.add(r, side);
        let p = g.gap(s);
        let d = g.dense(p, 2);
        g.loss(d);
        let (f, fused) = fuse_conv_bn_relu(&g);
        assert_eq!(fused, 0);
        assert_eq!(f.num_nodes(), g.num_nodes());
    }

    #[test]
    fn shapes_and_costs_preserved() {
        let g = zoo::resnet20_v1();
        let (f, _) = fuse_conv_bn_relu(&g);
        // Same logits shape, roughly same FLOPs (fused adds the BN epilogue
        // into the conv node's cost model).
        let gl = g.loss_node().unwrap();
        let fl = f.loss_node().unwrap();
        assert_eq!(g.nodes[gl].out_shape, f.nodes[fl].out_shape);
        let ratio = f.total_flops() / g.total_flops();
        assert!((0.95..1.05).contains(&ratio), "flops ratio {ratio}");
    }

    #[test]
    fn vgg_has_no_bn_so_nothing_fuses() {
        let g = zoo::vgg16(&[3, 32, 32], 10);
        let (_, fused) = fuse_conv_bn_relu(&g);
        assert_eq!(fused, 0);
    }
}
