//! The user-facing API — the Rust equivalent of the paper's Listing 2:
//!
//! ```python
//! model = ...  # any Keras model
//! hf.fit(model, num_partitions, num_replicas, strategy)
//! ```
//!
//! becomes
//!
//! ```ignore
//! let cfg = TrainConfig::new(zoo::resnet20_v1(), Strategy::Hybrid)
//!     .partitions(4).replicas(2).steps(50);
//! let result = fit(&cfg)?;
//! ```
//!
//! `fit` is fully user-transparent: no change to the model definition, no
//! manual communication — the Model Generator, Load Balancer, Trainer and
//! Communication Engine do the rest (paper Fig 4).

use crate::comm::CommEngine;
use crate::data::SyntheticDataset;
use crate::engine::{EngineConfig, StepMetrics, Trainer};
use crate::graph::{ModelGraph, NodeId};
use crate::hfmpi::{AllreduceAlgo, Transport, World};
use crate::partition::Partitioning;
use crate::runtime::Runtime;
use crate::schedule::{Program, ScheduleKind, SendMode};
use crate::tensor::Tensor;
use std::path::PathBuf;

/// Parallelization strategy (the paper's 4th user input).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Single process, whole model (the paper's "Sequential" baseline).
    Sequential,
    /// Model-parallel only: `partitions` ranks, one replica.
    Model,
    /// Data-parallel only: one partition, `replicas` ranks.
    Data,
    /// Model + data parallel: `partitions * replicas` ranks.
    Hybrid,
}

impl Strategy {
    pub fn parse(s: &str) -> anyhow::Result<Strategy> {
        Ok(match s {
            "seq" | "sequential" => Strategy::Sequential,
            "model" | "mp" => Strategy::Model,
            "data" | "dp" => Strategy::Data,
            "hybrid" => Strategy::Hybrid,
            _ => anyhow::bail!("unknown strategy '{s}' (seq|model|data|hybrid)"),
        })
    }
}

/// Everything `fit` needs. Builder-style setters keep call sites compact.
#[derive(Clone)]
pub struct TrainConfig {
    pub model: ModelGraph,
    pub strategy: Strategy,
    pub partitions: usize,
    pub replicas: usize,
    /// Expert knob (paper §5.1): explicit nodes-per-partition.
    pub lpp: Option<Vec<usize>>,
    pub engine: EngineConfig,
    pub steps: usize,
    /// Test microbatches for the final evaluation (0 = skip).
    pub eval_batches: usize,
    pub artifacts_dir: PathBuf,
    pub fusion_threshold: usize,
    pub allreduce_algo: AllreduceAlgo,
    /// Print a progress line every N steps (0 = silent).
    pub log_every: usize,
    /// Dataset override (defaults to a synthetic set matching the model's
    /// input shape and class count).
    pub dataset: Option<SyntheticDataset>,
    /// Worker threads for the native compute kernels (None = resolve from
    /// `HF_NATIVE_THREADS`, else an equal share of the machine per rank).
    /// Kernels are bitwise deterministic in the thread count.
    pub native_threads: Option<usize>,
    /// Point-to-point transport of the hfmpi fabric (default:
    /// `HF_TRANSPORT`, else buffered). Bitwise-neutral whenever a run
    /// completes — payloads and arithmetic are transport-independent —
    /// but blocking 1F1B-family sends deadlock under rendezvous; eager
    /// sends (the default) are safe on both.
    pub transport: Transport,
    /// Deadlock-watchdog timeout for the spawned world (None =
    /// `HFMPI_TIMEOUT_SECS`, default 120s).
    pub comm_timeout: Option<std::time::Duration>,
}

impl TrainConfig {
    pub fn new(model: ModelGraph, strategy: Strategy) -> Self {
        TrainConfig {
            model,
            strategy,
            partitions: 1,
            replicas: 1,
            lpp: None,
            engine: EngineConfig::default(),
            steps: 10,
            eval_batches: 0,
            artifacts_dir: default_artifacts_dir(),
            fusion_threshold: crate::hfmpi::DEFAULT_THRESHOLD_BYTES,
            allreduce_algo: AllreduceAlgo::Auto,
            log_every: 0,
            dataset: None,
            native_threads: None,
            transport: Transport::from_env().unwrap_or_else(|e| panic!("{e:#}")),
            comm_timeout: None,
        }
    }

    pub fn partitions(mut self, p: usize) -> Self {
        self.partitions = p;
        self
    }

    pub fn replicas(mut self, r: usize) -> Self {
        self.replicas = r;
        self
    }

    pub fn steps(mut self, s: usize) -> Self {
        self.steps = s;
        self
    }

    pub fn lpp(mut self, lpp: Vec<usize>) -> Self {
        self.lpp = Some(lpp);
        self
    }

    pub fn microbatch(mut self, mb: usize) -> Self {
        self.engine.microbatch = mb;
        self
    }

    pub fn num_microbatches(mut self, m: usize) -> Self {
        self.engine.num_microbatches = m;
        self
    }

    /// Pipeline schedule (paper's GPipe-style fill/drain, or 1F1B with
    /// bounded in-flight microbatches). One IR drives the Trainer, the
    /// simulator and the memory model — see `crate::schedule`.
    pub fn schedule(mut self, s: ScheduleKind) -> Self {
        self.engine.schedule = s;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.engine.lr = lr;
        self
    }

    /// Per-step learning-rate schedule (overrides `lr`).
    pub fn lr_schedule(mut self, s: crate::engine::LrSchedule) -> Self {
        self.engine.lr_schedule = Some(s);
        self
    }

    /// Eager (`PostSend*`/`WaitSend`) vs blocking IR sends — bitwise
    /// identical training either way; eager is also rendezvous-safe.
    /// Default: eager unless `HF_EAGER_SENDS=0`.
    pub fn eager_sends(mut self, on: bool) -> Self {
        self.engine.eager_sends = on;
        self
    }

    /// Record a per-rank hftrace of the run (schedule-IR spans, comm
    /// sub-spans, kernel spans) into [`FitResult::trace`]. Observation
    /// only: the trained model is bitwise identical either way.
    /// Default: off unless `HF_TRACE=1`.
    pub fn trace(mut self, on: bool) -> Self {
        self.engine.trace = on;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.engine.seed = s;
        self
    }

    pub fn log_every(mut self, n: usize) -> Self {
        self.log_every = n;
        self
    }

    pub fn eval_batches(mut self, n: usize) -> Self {
        self.eval_batches = n;
        self
    }

    pub fn dataset(mut self, d: SyntheticDataset) -> Self {
        self.dataset = Some(d);
        self
    }

    /// Worker threads for the native compute kernels (default: one equal
    /// share of the machine per rank; `HF_NATIVE_THREADS` overrides the
    /// default). Results are bitwise identical at any thread count.
    pub fn native_threads(mut self, t: usize) -> Self {
        self.native_threads = Some(t);
        self
    }

    /// Point-to-point transport for the run's hfmpi world (see the field
    /// docs; `HF_TRANSPORT=buffered|rendezvous` sets the default).
    pub fn transport(mut self, t: Transport) -> Self {
        self.transport = t;
        self
    }

    /// Deadlock-watchdog timeout override for the run's hfmpi world.
    pub fn comm_timeout(mut self, d: std::time::Duration) -> Self {
        self.comm_timeout = Some(d);
        self
    }

    /// Effective (partitions, replicas) after strategy normalization.
    pub fn effective_topology(&self) -> (usize, usize) {
        match self.strategy {
            Strategy::Sequential => (1, 1),
            Strategy::Model => (self.partitions, 1),
            Strategy::Data => (1, self.replicas),
            Strategy::Hybrid => (self.partitions, self.replicas),
        }
    }
}

/// Default artifacts directory: $HYPARFLOW_ARTIFACTS or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("HYPARFLOW_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The outcome of a training run.
pub struct FitResult {
    /// Per-step metrics (replica-averaged, reported by the last partition).
    pub history: Vec<StepMetrics>,
    /// Final held-out evaluation, if requested.
    pub eval: Option<StepMetrics>,
    /// Full model parameters from replica 0 (merged across partitions),
    /// keyed by (node, slot).
    pub params: Vec<((NodeId, usize), Tensor)>,
    pub wall_secs: f64,
    /// Throughput in the paper's metric.
    pub img_per_sec: f64,
    /// Merged per-rank hftrace (world-rank order), when
    /// [`TrainConfig::trace`] was enabled.
    pub trace: Option<crate::trace::Trace>,
}

impl FitResult {
    pub fn final_loss(&self) -> f32 {
        self.history.last().map(|m| m.loss).unwrap_or(f32::NAN)
    }

    pub fn param(&self, node: NodeId, slot: usize) -> Option<&Tensor> {
        self.params
            .iter()
            .find(|((n, s), _)| *n == node && *s == slot)
            .map(|(_, t)| t)
    }
}

struct RankOutput {
    history: Vec<StepMetrics>,
    eval: Option<StepMetrics>,
    params: Vec<((NodeId, usize), Tensor)>,
    trace: Option<crate::trace::RankTrace>,
}

/// Train. Spawns `partitions x replicas` ranks on the hfmpi fabric, each
/// loading the AOT artifacts through its own PJRT client, and runs
/// `cfg.steps` synchronous steps.
pub fn fit(cfg: &TrainConfig) -> anyhow::Result<FitResult> {
    cfg.model.validate()?;
    let (p, r) = cfg.effective_topology();
    anyhow::ensure!(p >= 1 && r >= 1, "need at least 1 partition and 1 replica");
    // Interleaved schedules partition at stage granularity: `p * v`
    // contiguous chunks mapped round-robin onto the `p` pipeline ranks.
    let stages = p * cfg.engine.schedule.virtual_stages();
    let pt = match &cfg.lpp {
        Some(lpp) => {
            let pt = Partitioning::from_lpp(&cfg.model, lpp)?;
            anyhow::ensure!(
                pt.num_partitions == stages,
                "lpp defines {} partitions but schedule {} over {p} ranks needs {stages} stages",
                pt.num_partitions,
                cfg.engine.schedule.label(),
            );
            pt
        }
        None => Partitioning::auto(&cfg.model, stages)?,
    };
    let dataset = cfg.dataset.clone().unwrap_or_else(|| {
        SyntheticDataset::new(
            cfg.engine.seed,
            num_classes(&cfg.model),
            &cfg.model.input_shape,
            1.0,
        )
    });
    anyhow::ensure!(
        dataset.sample_shape == cfg.model.input_shape,
        "dataset sample shape {:?} != model input {:?}",
        dataset.sample_shape,
        cfg.model.input_shape
    );

    let t0 = std::time::Instant::now();
    let world_n = p * r;
    // Kernel worker threads: explicit config > HF_NATIVE_THREADS env > an
    // equal share of the machine per rank. Thread count never changes
    // results (kernels are bitwise deterministic), only speed.
    let threads = cfg
        .native_threads
        .or_else(crate::runtime::pool::env_threads)
        .unwrap_or_else(|| {
            let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
            (avail / world_n).max(1)
        });
    crate::runtime::pool::set_num_threads(threads);
    let outputs: Vec<anyhow::Result<RankOutput>> =
        World::run_with(world_n, cfg.transport, cfg.comm_timeout, |world| {
            run_rank(cfg, &pt, world, p, &dataset)
        });
    let wall = t0.elapsed().as_secs_f64();

    // Merge rank outputs.
    let mut history = vec![];
    let mut eval = None;
    let mut params = vec![];
    let mut rank_traces = vec![];
    for (rank, out) in outputs.into_iter().enumerate() {
        let out = out.map_err(|e| anyhow::anyhow!("rank {rank}: {e}"))?;
        let partition = rank % p;
        let replica = rank / p;
        if partition == p - 1 && replica == 0 {
            history = out.history;
            eval = out.eval;
        }
        if replica == 0 {
            params.extend(out.params);
        }
        if let Some(tr) = out.trace {
            rank_traces.push(tr);
        }
    }
    params.sort_by_key(|((n, s), _)| (*n, *s));
    let trace = if rank_traces.is_empty() {
        None
    } else {
        // World::run returns outputs in rank order, so the merged trace's
        // index i is world rank i.
        Some(crate::trace::Trace { ranks: rank_traces })
    };
    let total_samples = cfg.steps * cfg.engine.microbatch * cfg.engine.num_microbatches * r;
    Ok(FitResult {
        history,
        eval,
        params,
        wall_secs: wall,
        img_per_sec: total_samples as f64 / wall,
        trace,
    })
}

fn run_rank(
    cfg: &TrainConfig,
    pt: &Partitioning,
    world: &crate::hfmpi::Comm,
    partitions: usize,
    dataset: &SyntheticDataset,
) -> anyhow::Result<RankOutput> {
    // Budget-check the eager-send concurrency against the tag space up
    // front: the worst-case in-flight count is a static property of the
    // compiled program (the trainer compiles the identical program).
    let mode = if cfg.engine.eager_sends { SendMode::Eager } else { SendMode::Blocking };
    let max_in_flight = Program::compile_with(
        &cfg.model,
        pt,
        cfg.engine.num_microbatches,
        cfg.engine.schedule,
        mode,
    )
    .max_in_flight_sends();
    let ce = CommEngine::new(
        world,
        partitions,
        pt.edges.len(),
        cfg.engine.num_microbatches,
        max_in_flight,
        cfg.fusion_threshold,
        cfg.allreduce_algo,
    );
    let rt = Runtime::open(&cfg.artifacts_dir)?;
    let mut trainer =
        Trainer::new(&cfg.model, pt, cfg.engine.clone(), &ce, &rt, dataset.clone())?;
    let names = trainer.artifact_names();
    rt.warmup(names.iter().map(|s| s.as_str()))?;

    // Attach one hftrace handle per rank, after warmup so compile-time
    // plan caching never shows up as kernel spans. All three layers share
    // the same buffer: comm sub-spans and kernel spans nest inside the
    // Trainer's schedule-IR spans on the timeline.
    let tracer = if cfg.engine.trace {
        crate::trace::Tracer::on(world.rank())
    } else {
        crate::trace::Tracer::off()
    };
    ce.attach_tracer(tracer.clone());
    rt.attach_tracer(tracer.clone());
    trainer.set_tracer(tracer.clone());

    let is_reporter = ce.partition == partitions - 1 && ce.replica_id == 0;
    let mut history = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let m = trainer.train_step(step as u64)?;
        if is_reporter && cfg.log_every > 0 && (step + 1) % cfg.log_every == 0 {
            println!(
                "step {:>5}/{}: loss={:.4} acc={:.3} ({:.1} img/s)",
                step + 1,
                cfg.steps,
                m.loss,
                m.accuracy,
                m.samples as f64 / m.step_secs
            );
        }
        history.push(m);
    }
    let eval = if cfg.eval_batches > 0 {
        Some(trainer.evaluate(cfg.eval_batches)?)
    } else {
        None
    };
    let trace = tracer.take();
    Ok(RankOutput { history, eval, params: trainer.export_params(), trace })
}

fn num_classes(g: &ModelGraph) -> usize {
    g.loss_node().map(|l| g.nodes[l].out_shape[0]).unwrap_or(10)
}
