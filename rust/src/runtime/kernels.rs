//! Blocked, register-tiled, multi-threaded compute kernels for the native
//! executor — the single hottest path in the crate (every FwdCompute /
//! BwdCompute in the schedule IR bottoms out here).
//!
//! # Blocking strategy
//!
//! `matmul` is a GotoBLAS-style panel kernel: B is packed once into
//! KC-row, NR-column panels (one panel is `KC*NR*4` = 16 KiB, L1-resident
//! while a row tile streams over it), and an MR x NR register-tiled
//! microkernel accumulates `plen` rank-1 updates per k-block. The generic
//! microkernel is written so LLVM keeps the MR x NR accumulator tile in
//! vector registers (const-generic row count, fixed NR lanes); on x86-64
//! an explicit AVX2 6x16 microkernel is selected by runtime CPU detection,
//! with the autovectorized generic path as the fallback on older CPUs and
//! other architectures. `matmul_tn` (`a^T @ b`) is a cache-blocked
//! transpose followed by the same blocked `matmul`. `im2col`, `col2im`,
//! the conv layout permutes, and the dense epilogues are parallelized over
//! rows / planes via [`super::pool`].
//!
//! # Determinism contract (load-bearing)
//!
//! Every kernel is **bitwise identical** to the scalar reference in
//! [`scalar`] at any thread count. The sequential-vs-parallel training
//! equivalence tests stand on this. Three rules make it hold:
//!
//! 1. **Accumulation order per output element never changes.** The
//!    microkernel loads its accumulator tile *from the current output*,
//!    adds the k-block's contributions in ascending-k order, and stores it
//!    back; k-blocks run in ascending order. Each output element therefore
//!    sees the exact `((0 + a0*b0) + a1*b1) + ...` chain of the scalar
//!    i-k-j loop. (Zero-init + add-back would reassociate — forbidden.)
//! 2. **No FMA.** Rust never contracts `a * b + c`, and the AVX2 path uses
//!    `_mm256_mul_ps` + `_mm256_add_ps` rather than `_mm256_fmadd_ps`: a
//!    fused multiply-add rounds once where the scalar reference rounds
//!    twice, which would break bit-parity.
//! 3. **Parallelism only over disjoint outputs.** Threads own disjoint
//!    row/plane spans of the output; SIMD lanes map to distinct columns.
//!    Nothing ever splits a single element's reduction.
//!
//! Small problems run serially (see `PAR_MIN_*`): the cutoff depends only
//! on the problem size, never on data or thread count, so it is part of
//! the deterministic contract rather than a violation of it.

use super::pool;
use crate::tensor::{Shape, Tensor};

/// Microkernel register tile: MR output rows x NR output columns.
/// 6 x 16 f32 = twelve 8-lane vectors of accumulator — with a broadcast
/// register and two panel loads this exactly fills the 16 ymm registers
/// of an AVX2 core (the classic 6x16 sgemm tile).
pub const MR: usize = 6;
pub const NR: usize = 16;
/// k-dimension block: one packed panel is `KC * NR` floats (16 KiB).
pub const KC: usize = 256;

/// Minimum `m*k*n` for a threaded matmul; below this the spawn cost
/// (tens of microseconds) exceeds the work. Size-only: deterministic.
const PAR_MIN_FLOPS: usize = 1 << 18;
/// Minimum element count for threaded copy/permute/scatter passes.
const PAR_MIN_ELEMS: usize = 1 << 16;

// ---------------------------------------------------------------------------
// Scalar reference kernels
// ---------------------------------------------------------------------------

/// The original single-threaded scalar kernels, kept verbatim as the
/// bitwise reference for the blocked implementations (equivalence tests in
/// `rust/tests/kernel_equivalence.rs`) and as the baseline the kernel
/// benchmark measures speedups against.
pub mod scalar {
    use crate::tensor::{Shape, Tensor};

    /// `a [m,k] @ b [k,n]` with i-k-j loop order (deterministic).
    pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// `a^T @ b` for `a [m,k]`, `b [m,n]` -> `[k,n]` (accumulates over
    /// rows of both, ascending).
    pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; k * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let orow = &mut out[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// Patch matrix [N*Ho*Wo, C*kk*kk]; feature index = (c*kk + dy)*kk + dx
    /// — the OIHW-flatten ordering `model.py::_patches` produces.
    pub fn im2col(x: &Tensor, kk: usize, stride: usize) -> (Vec<f32>, usize, usize) {
        let d = x.shape.dims();
        let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
        let pad = kk / 2;
        let (ho, wo) = (h.div_ceil(stride), w.div_ceil(stride));
        let f = c * kk * kk;
        let mut out = vec![0.0f32; n * ho * wo * f];
        for nn in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((nn * ho + oy) * wo + ox) * f;
                    for ci in 0..c {
                        for dy in 0..kk {
                            let iy = (oy * stride + dy) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let xbase = ((nn * c + ci) * h + iy as usize) * w;
                            for dx in 0..kk {
                                let ix = (ox * stride + dx) as isize - pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                out[row + (ci * kk + dy) * kk + dx] = x.data[xbase + ix as usize];
                            }
                        }
                    }
                }
            }
        }
        (out, ho, wo)
    }

    /// Scatter-add the patch-matrix gradient back into input layout (the
    /// VJP of `im2col`). Deterministic ascending iteration.
    pub fn col2im(
        gp: &[f32],
        n: usize,
        c: usize,
        h: usize,
        w: usize,
        kk: usize,
        stride: usize,
    ) -> Tensor {
        let pad = kk / 2;
        let (ho, wo) = (h.div_ceil(stride), w.div_ceil(stride));
        let f = c * kk * kk;
        let mut gx = vec![0.0f32; n * c * h * w];
        for nn in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((nn * ho + oy) * wo + ox) * f;
                    for ci in 0..c {
                        for dy in 0..kk {
                            let iy = (oy * stride + dy) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            let xbase = ((nn * c + ci) * h + iy as usize) * w;
                            for dx in 0..kk {
                                let ix = (ox * stride + dx) as isize - pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                gx[xbase + ix as usize] += gp[row + (ci * kk + dy) * kk + dx];
                            }
                        }
                    }
                }
            }
        }
        Tensor::new(Shape::new(&[n, c, h, w]), gx)
    }

    pub fn conv2d_fwd(x: &Tensor, w: &Tensor, kk: usize, stride: usize) -> Tensor {
        let xd = x.shape.dims();
        let (n, c) = (xd[0], xd[1]);
        let kout = w.shape.dims()[0];
        let f = c * kk * kk;
        let (pmat, ho, wo) = im2col(x, kk, stride);
        // wmat = w.reshape(k, f).T -> [f, k]
        let mut wt = vec![0.0f32; f * kout];
        for ko in 0..kout {
            for fi in 0..f {
                wt[fi * kout + ko] = w.data[ko * f + fi];
            }
        }
        let ymat = matmul(&pmat, &wt, n * ho * wo, f, kout); // [M, K]
        // [M, K] -> NCHW
        let mut y = vec![0.0f32; n * kout * ho * wo];
        for nn in 0..n {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((nn * ho + oy) * wo + ox) * kout;
                    for ko in 0..kout {
                        y[((nn * kout + ko) * ho + oy) * wo + ox] = ymat[row + ko];
                    }
                }
            }
        }
        Tensor::new(Shape::new(&[n, kout, ho, wo]), y)
    }

    pub fn conv2d_bwd(
        x: &Tensor,
        w: &Tensor,
        gy: &Tensor,
        kk: usize,
        stride: usize,
    ) -> (Tensor, Tensor) {
        let xd = x.shape.dims();
        let (n, c, h, wd) = (xd[0], xd[1], xd[2], xd[3]);
        let kout = w.shape.dims()[0];
        let f = c * kk * kk;
        let gyd = gy.shape.dims();
        let (ho, wo) = (gyd[2], gyd[3]);
        let mrows = n * ho * wo;
        let (pmat, _, _) = im2col(x, kk, stride);
        // gy NCHW -> [M, K]
        let mut gymat = vec![0.0f32; mrows * kout];
        for nn in 0..n {
            for ko in 0..kout {
                for oy in 0..ho {
                    for ox in 0..wo {
                        gymat[(((nn * ho + oy) * wo + ox) * kout) + ko] =
                            gy.data[((nn * kout + ko) * ho + oy) * wo + ox];
                    }
                }
            }
        }
        // gw = pmat^T @ gymat : [F, K] -> transpose-reshape to [K, C, kk, kk].
        let gwmat = matmul_tn(&pmat, &gymat, mrows, f, kout);
        let mut gw = vec![0.0f32; kout * f];
        for fi in 0..f {
            for ko in 0..kout {
                gw[ko * f + fi] = gwmat[fi * kout + ko];
            }
        }
        // gpatches = gymat @ w.reshape(k, f) : [M, F] -> col2im.
        let gpmat = matmul(&gymat, &w.data, mrows, kout, f);
        let gx = col2im(&gpmat, n, c, h, wd, kk, stride);
        (gx, Tensor::new(w.shape.clone(), gw))
    }

    pub fn dense_fwd(x: &Tensor, w: &Tensor, b: &Tensor, relu: bool) -> Tensor {
        let (n, d) = (x.shape.dims()[0], x.shape.dims()[1]);
        let m = w.shape.dims()[1];
        let mut y = matmul(&x.data, &w.data, n, d, m);
        for row in 0..n {
            for j in 0..m {
                let v = y[row * m + j] + b.data[j];
                y[row * m + j] = if relu { v.max(0.0) } else { v };
            }
        }
        Tensor::new(Shape::new(&[n, m]), y)
    }

    pub fn dense_bwd(x: &Tensor, w: &Tensor, gy: &Tensor) -> (Tensor, Tensor, Tensor) {
        let (n, d) = (x.shape.dims()[0], x.shape.dims()[1]);
        let m = w.shape.dims()[1];
        // gx = gy @ w^T : [N, D]
        let mut wt = vec![0.0f32; m * d];
        for di in 0..d {
            for mi in 0..m {
                wt[mi * d + di] = w.data[di * m + mi];
            }
        }
        let gx = matmul(&gy.data, &wt, n, m, d);
        // gw = x^T @ gy : [D, M]
        let gw = matmul_tn(&x.data, &gy.data, n, d, m);
        // gb = column sums of gy.
        let mut gb = vec![0.0f32; m];
        for row in 0..n {
            for j in 0..m {
                gb[j] += gy.data[row * m + j];
            }
        }
        (
            Tensor::new(Shape::new(&[n, d]), gx),
            Tensor::new(Shape::new(&[d, m]), gw),
            Tensor::new(Shape::new(&[m]), gb),
        )
    }
}

// ---------------------------------------------------------------------------
// SIMD backend selection
// ---------------------------------------------------------------------------

/// Is the AVX2 microkernel usable on this CPU? (Runtime detection; the
/// result is cached by the std macro.)
#[cfg(target_arch = "x86_64")]
pub fn avx2_enabled() -> bool {
    std::is_x86_feature_detected!("avx2")
}

/// Non-x86-64 targets always use the portable autovectorized microkernel.
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_enabled() -> bool {
    false
}

/// Human-readable name of the active microkernel backend (for bench JSON).
pub fn simd_backend() -> &'static str {
    if avx2_enabled() {
        "avx2"
    } else {
        "portable"
    }
}

// ---------------------------------------------------------------------------
// Packing and microkernels
// ---------------------------------------------------------------------------

/// Cache-blocked out-of-place transpose: `src [rows, cols]` -> `[cols, rows]`.
fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    const TB: usize = 32;
    let mut dst = vec![0.0f32; rows * cols];
    for rb in (0..rows).step_by(TB) {
        for cb in (0..cols).step_by(TB) {
            for r in rb..rows.min(rb + TB) {
                for c in cb..cols.min(cb + TB) {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
    dst
}

/// Pack `b [k,n]` into KC-blocked, NR-wide panels: element (p, j) of
/// k-block `kb`, panel `jp` lives at `((kb*npanels + jp)*KC + p)*NR + j`.
/// Column tails are zero-padded to NR (the microkernel computes the padded
/// lanes but never stores them); row tails are simply not iterated.
fn pack_b(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    let npanels = n.div_ceil(NR);
    let nblocks = k.div_ceil(KC);
    let mut out = vec![0.0f32; nblocks * npanels * KC * NR];
    for kb in 0..nblocks {
        let p0 = kb * KC;
        let plen = KC.min(k - p0);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let jlen = NR.min(n - j0);
            let base = (kb * npanels + jp) * (KC * NR);
            for p in 0..plen {
                let src = &b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jlen];
                out[base + p * NR..base + p * NR + jlen].copy_from_slice(src);
            }
        }
    }
    out
}

/// Portable microkernel: accumulate `plen` rank-1 updates into a
/// ROWS x NR register tile. The accumulator is initialized *from the
/// current output* and stored back, so the per-element addition chain is
/// exactly the scalar one (rule 1 of the determinism contract). Lanes
/// beyond `jlen` accumulate against the panel's zero padding and are
/// never stored. `ar0` indexes rows of `a` (absolute); `or0` indexes rows
/// of `out` (chunk-local).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn mk_generic<const ROWS: usize>(
    a: &[f32],
    lda: usize,
    ar0: usize,
    p0: usize,
    plen: usize,
    panel: &[f32],
    out: &mut [f32],
    ldo: usize,
    or0: usize,
    j0: usize,
    jlen: usize,
) {
    let mut acc = [[0.0f32; NR]; ROWS];
    for r in 0..ROWS {
        let orow = &out[(or0 + r) * ldo + j0..(or0 + r) * ldo + j0 + jlen];
        acc[r][..jlen].copy_from_slice(orow);
    }
    for p in 0..plen {
        let prow = &panel[p * NR..(p + 1) * NR];
        for r in 0..ROWS {
            let av = a[(ar0 + r) * lda + p0 + p];
            for (o, &bv) in acc[r].iter_mut().zip(prow.iter()) {
                *o += av * bv;
            }
        }
    }
    for r in 0..ROWS {
        out[(or0 + r) * ldo + j0..(or0 + r) * ldo + j0 + jlen].copy_from_slice(&acc[r][..jlen]);
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// AVX2 MR x 16 microkernel (full tiles only). Uses separate
    /// `_mm256_mul_ps` + `_mm256_add_ps` — never `_mm256_fmadd_ps` — so
    /// each lane performs the same round-twice mul-then-add as the scalar
    /// reference (rule 2 of the determinism contract).
    ///
    /// # Safety
    ///
    /// Caller must guarantee AVX2 is available (runtime-detected), rows
    /// `ar0..ar0+MR` x cols `p0..p0+plen` are in bounds of `a` (row stride
    /// `lda`), `panel` holds at least `plen * NR` floats, and rows
    /// `or0..or0+MR` x cols `j0..j0+NR` are in bounds of `out` (row
    /// stride `ldo`).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn mk_avx2(
        a: &[f32],
        lda: usize,
        ar0: usize,
        p0: usize,
        plen: usize,
        panel: &[f32],
        out: &mut [f32],
        ldo: usize,
        or0: usize,
        j0: usize,
    ) {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        for r in 0..MR {
            let o = out.as_ptr().add((or0 + r) * ldo + j0);
            acc[r][0] = _mm256_loadu_ps(o);
            acc[r][1] = _mm256_loadu_ps(o.add(8));
        }
        let ap = a.as_ptr();
        let pp = panel.as_ptr();
        for p in 0..plen {
            let b0 = _mm256_loadu_ps(pp.add(p * NR));
            let b1 = _mm256_loadu_ps(pp.add(p * NR + 8));
            for r in 0..MR {
                let av = _mm256_set1_ps(*ap.add((ar0 + r) * lda + p0 + p));
                acc[r][0] = _mm256_add_ps(acc[r][0], _mm256_mul_ps(av, b0));
                acc[r][1] = _mm256_add_ps(acc[r][1], _mm256_mul_ps(av, b1));
            }
        }
        for r in 0..MR {
            let o = out.as_mut_ptr().add((or0 + r) * ldo + j0);
            _mm256_storeu_ps(o, acc[r][0]);
            _mm256_storeu_ps(o.add(8), acc[r][1]);
        }
    }
}

/// Dispatch one output tile to the best microkernel: AVX2 for full
/// MR x NR tiles when available, else the const-generic portable kernel.
#[allow(clippy::too_many_arguments)]
#[inline]
fn mk_tile(
    a: &[f32],
    lda: usize,
    ar0: usize,
    p0: usize,
    plen: usize,
    panel: &[f32],
    out: &mut [f32],
    ldo: usize,
    or0: usize,
    j0: usize,
    jlen: usize,
    rows: usize,
    avx2: bool,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2 && rows == MR && jlen == NR {
            // SAFETY: AVX2 was runtime-detected by the caller; the driver
            // only requests full tiles whose rows/cols are in bounds.
            unsafe { x86::mk_avx2(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0) };
            return;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = avx2;
    match rows {
        6 => mk_generic::<6>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
        5 => mk_generic::<5>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
        4 => mk_generic::<4>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
        3 => mk_generic::<3>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
        2 => mk_generic::<2>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
        _ => mk_generic::<1>(a, lda, ar0, p0, plen, panel, out, ldo, or0, j0, jlen),
    }
}

// ---------------------------------------------------------------------------
// Blocked drivers
// ---------------------------------------------------------------------------

/// Blocked, multi-threaded `a [m,k] @ b [k,n]`. Bitwise identical to
/// [`scalar::matmul`] at any thread count (see the module docs).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    matmul_into(a, b, &mut out, m, k, n);
    out
}

fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let packed = pack_b(b, k, n);
    let npanels = n.div_ceil(NR);
    let nblocks = k.div_ceil(KC);
    let avx2 = avx2_enabled();
    let threads = if m * k * n >= PAR_MIN_FLOPS { pool::num_threads() } else { 1 };
    // Each worker owns a contiguous MR-aligned span of output rows; within
    // it, k-blocks run ascending (outermost) so rule 1 holds, and each
    // packed panel stays hot across the span's row tiles.
    let chunk_rows = m.div_ceil(MR).div_ceil(threads).max(1) * MR;
    pool::par_chunks_mut_with(out, chunk_rows * n, threads, |ci, chunk| {
        let row0 = ci * chunk_rows;
        let rows = chunk.len() / n;
        for kb in 0..nblocks {
            let p0 = kb * KC;
            let plen = KC.min(k - p0);
            for jp in 0..npanels {
                let j0 = jp * NR;
                let jlen = NR.min(n - j0);
                let panel = &packed[(kb * npanels + jp) * (KC * NR)..][..plen * NR];
                let mut r = 0;
                while r < rows {
                    let tr = MR.min(rows - r);
                    mk_tile(a, k, row0 + r, p0, plen, panel, chunk, n, r, j0, jlen, tr, avx2);
                    r += MR;
                }
            }
        }
    });
}

/// Blocked `a^T @ b` for `a [m,k]`, `b [m,n]` -> `[k,n]`: a cache-blocked
/// transpose of A followed by the blocked [`matmul`]. The accumulation
/// dimension is the same ascending row index `i` either way, so this is
/// bitwise identical to [`scalar::matmul_tn`].
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let at = transpose(a, m, k); // [k, m]
    matmul_into(&at, b, &mut out, k, m, n);
    out
}

// ---------------------------------------------------------------------------
// conv2d via im2col (SAME padding, odd square kernel, NCHW/OIHW)
// ---------------------------------------------------------------------------

/// Row-parallel im2col: one patch row per output position; rows are
/// disjoint output spans, so this is trivially bitwise-safe.
pub fn im2col(x: &Tensor, kk: usize, stride: usize) -> (Vec<f32>, usize, usize) {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let pad = kk / 2;
    let (ho, wo) = (h.div_ceil(stride), w.div_ceil(stride));
    let f = c * kk * kk;
    let mut out = vec![0.0f32; n * ho * wo * f];
    let threads = if out.len() >= PAR_MIN_ELEMS { pool::num_threads() } else { 1 };
    pool::par_chunks_mut_with(&mut out, f, threads, |row, dst| {
        let nn = row / (ho * wo);
        let oy = (row / wo) % ho;
        let ox = row % wo;
        for ci in 0..c {
            for dy in 0..kk {
                let iy = (oy * stride + dy) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                let xbase = ((nn * c + ci) * h + iy as usize) * w;
                for dx in 0..kk {
                    let ix = (ox * stride + dx) as isize - pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    dst[(ci * kk + dy) * kk + dx] = x.data[xbase + ix as usize];
                }
            }
        }
    });
    (out, ho, wo)
}

/// Plane-parallel col2im scatter-add (the VJP of [`im2col`]). Each worker
/// owns whole (image, channel) planes of `gx`; within a plane the
/// contributions to each element arrive in the scalar kernel's ascending
/// (oy, ox, dy, dx) order — the channel loop in the scalar nest only
/// *selects* elements of other planes, it never reorders contributions
/// within one — so the result is bitwise identical at any thread count.
pub fn col2im(
    gp: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kk: usize,
    stride: usize,
) -> Tensor {
    let pad = kk / 2;
    let (ho, wo) = (h.div_ceil(stride), w.div_ceil(stride));
    let f = c * kk * kk;
    let mut gx = vec![0.0f32; n * c * h * w];
    let threads = if gp.len() >= PAR_MIN_ELEMS { pool::num_threads() } else { 1 };
    pool::par_chunks_mut_with(&mut gx, h * w, threads, |plane, dst| {
        let nn = plane / c;
        let ci = plane % c;
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((nn * ho + oy) * wo + ox) * f;
                for dy in 0..kk {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    let dbase = iy as usize * w;
                    for dx in 0..kk {
                        let ix = (ox * stride + dx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        dst[dbase + ix as usize] += gp[row + (ci * kk + dy) * kk + dx];
                    }
                }
            }
        }
    });
    Tensor::new(Shape::new(&[n, c, h, w]), gx)
}

pub fn conv2d_fwd(x: &Tensor, w: &Tensor, kk: usize, stride: usize) -> Tensor {
    let xd = x.shape.dims();
    let (n, c) = (xd[0], xd[1]);
    let kout = w.shape.dims()[0];
    let f = c * kk * kk;
    let (pmat, ho, wo) = im2col(x, kk, stride);
    let wt = transpose(&w.data, kout, f); // w.reshape(k, f).T -> [f, k]
    let ymat = matmul(&pmat, &wt, n * ho * wo, f, kout); // [M, K]
    // [M, K] -> NCHW, one (image, out-channel) plane per chunk.
    let mut y = vec![0.0f32; n * kout * ho * wo];
    let threads = if y.len() >= PAR_MIN_ELEMS { pool::num_threads() } else { 1 };
    pool::par_chunks_mut_with(&mut y, ho * wo, threads, |plane, dst| {
        let nn = plane / kout;
        let ko = plane % kout;
        for oy in 0..ho {
            for ox in 0..wo {
                dst[oy * wo + ox] = ymat[((nn * ho + oy) * wo + ox) * kout + ko];
            }
        }
    });
    Tensor::new(Shape::new(&[n, kout, ho, wo]), y)
}

pub fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    gy: &Tensor,
    kk: usize,
    stride: usize,
) -> (Tensor, Tensor) {
    let xd = x.shape.dims();
    let (n, c, h, wd) = (xd[0], xd[1], xd[2], xd[3]);
    let kout = w.shape.dims()[0];
    let f = c * kk * kk;
    let gyd = gy.shape.dims();
    let (ho, wo) = (gyd[2], gyd[3]);
    let mrows = n * ho * wo;
    let (pmat, _, _) = im2col(x, kk, stride);
    // gy NCHW -> [M, K], one patch row per chunk (pure copies: any
    // iteration order gives identical bytes).
    let mut gymat = vec![0.0f32; mrows * kout];
    let threads = if gymat.len() >= PAR_MIN_ELEMS { pool::num_threads() } else { 1 };
    pool::par_chunks_mut_with(&mut gymat, kout, threads, |row, dst| {
        let nn = row / (ho * wo);
        let oy = (row / wo) % ho;
        let ox = row % wo;
        for (ko, d) in dst.iter_mut().enumerate() {
            *d = gy.data[((nn * kout + ko) * ho + oy) * wo + ox];
        }
    });
    // gw = pmat^T @ gymat : [F, K] -> transpose-reshape to [K, C, kk, kk].
    let gwmat = matmul_tn(&pmat, &gymat, mrows, f, kout);
    let gw = transpose(&gwmat, f, kout); // [K, f] == OIHW-flat
    // gpatches = gymat @ w.reshape(k, f) : [M, F] -> col2im.
    let gpmat = matmul(&gymat, &w.data, mrows, kout, f);
    let gx = col2im(&gpmat, n, c, h, wd, kk, stride);
    (gx, Tensor::new(w.shape.clone(), gw))
}

// ---------------------------------------------------------------------------
// dense
// ---------------------------------------------------------------------------

pub fn dense_fwd(x: &Tensor, w: &Tensor, b: &Tensor, relu: bool) -> Tensor {
    let (n, d) = (x.shape.dims()[0], x.shape.dims()[1]);
    let m = w.shape.dims()[1];
    let mut y = matmul(&x.data, &w.data, n, d, m);
    // Bias + activation epilogue, row-parallel (same per-element ops and
    // order as the scalar reference).
    let threads = if y.len() >= PAR_MIN_ELEMS { pool::num_threads() } else { 1 };
    pool::par_chunks_mut_with(&mut y, m, threads, |_row, yr| {
        for (v, &bv) in yr.iter_mut().zip(b.data.iter()) {
            let s = *v + bv;
            *v = if relu { s.max(0.0) } else { s };
        }
    });
    Tensor::new(Shape::new(&[n, m]), y)
}

pub fn dense_bwd(x: &Tensor, w: &Tensor, gy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let (n, d) = (x.shape.dims()[0], x.shape.dims()[1]);
    let m = w.shape.dims()[1];
    // gx = gy @ w^T : [N, D]
    let wt = transpose(&w.data, d, m); // [m, d]
    let gx = matmul(&gy.data, &wt, n, m, d);
    // gw = x^T @ gy : [D, M]
    let gw = matmul_tn(&x.data, &gy.data, n, d, m);
    // gb = column sums of gy, ascending rows (small; serial).
    let mut gb = vec![0.0f32; m];
    for row in 0..n {
        for (g, &v) in gb.iter_mut().zip(gy.data[row * m..(row + 1) * m].iter()) {
            *g += v;
        }
    }
    (
        Tensor::new(Shape::new(&[n, d]), gx),
        Tensor::new(Shape::new(&[d, m]), gw),
        Tensor::new(Shape::new(&[m]), gb),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    // The heavy proptest-style sweeps (random shapes x thread counts) live
    // in rust/tests/kernel_equivalence.rs, a separate process, so they can
    // drive the global thread knob without racing other lib tests. These
    // in-module tests pin down the packing/microkernel math at the current
    // thread setting.

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
        }
    }

    #[test]
    fn blocked_matmul_matches_scalar_bitwise() {
        let mut rng = Rng::new(42);
        for (m, k, n) in [
            (1usize, 1usize, 1usize),
            (6, 256, 16),  // exactly one tile / panel / k-block
            (7, 257, 17),  // one past every boundary
            (5, 255, 15),  // one short of every boundary
            (13, 500, 40),
            (64, 300, 33),
        ] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let want = scalar::matmul(&a, &b, m, k, n);
            let got = matmul(&a, &b, m, k, n);
            assert_bits_eq(&want, &got, &format!("matmul {m}x{k}x{n}"));
        }
    }

    #[test]
    fn blocked_matmul_tn_matches_scalar_bitwise() {
        let mut rng = Rng::new(43);
        for (m, k, n) in [(1usize, 1usize, 1usize), (37, 19, 23), (300, 18, 40)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, m * n);
            let want = scalar::matmul_tn(&a, &b, m, k, n);
            let got = matmul_tn(&a, &b, m, k, n);
            assert_bits_eq(&want, &got, &format!("matmul_tn {m}x{k}x{n}"));
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(44);
        let src = randv(&mut rng, 37 * 65);
        let t = transpose(&src, 37, 65);
        let back = transpose(&t, 65, 37);
        assert_bits_eq(&src, &back, "transpose roundtrip");
        assert_eq!(t[5 * 37 + 3], src[3 * 65 + 5]);
    }

    #[test]
    fn conv_and_dense_match_scalar_bitwise() {
        let mut rng = Rng::new(45);
        let x = Tensor::randn(&[2, 3, 8, 8], 1.0, &mut rng);
        let w = Tensor::randn(&[4, 3, 3, 3], 0.5, &mut rng);
        let gy = Tensor::randn(&[2, 4, 8, 8], 1.0, &mut rng);
        assert_bits_eq(
            &scalar::conv2d_fwd(&x, &w, 3, 1).data,
            &conv2d_fwd(&x, &w, 3, 1).data,
            "conv fwd",
        );
        let (gx0, gw0) = scalar::conv2d_bwd(&x, &w, &gy, 3, 1);
        let (gx1, gw1) = conv2d_bwd(&x, &w, &gy, 3, 1);
        assert_bits_eq(&gx0.data, &gx1.data, "conv bwd gx");
        assert_bits_eq(&gw0.data, &gw1.data, "conv bwd gw");

        let dx = Tensor::randn(&[5, 33], 1.0, &mut rng);
        let dw = Tensor::randn(&[33, 17], 0.5, &mut rng);
        let db = Tensor::randn(&[17], 0.1, &mut rng);
        let dgy = Tensor::randn(&[5, 17], 1.0, &mut rng);
        assert_bits_eq(
            &scalar::dense_fwd(&dx, &dw, &db, true).data,
            &dense_fwd(&dx, &dw, &db, true).data,
            "dense fwd",
        );
        let (a0, b0, c0) = scalar::dense_bwd(&dx, &dw, &dgy);
        let (a1, b1, c1) = dense_bwd(&dx, &dw, &dgy);
        assert_bits_eq(&a0.data, &a1.data, "dense bwd gx");
        assert_bits_eq(&b0.data, &b1.data, "dense bwd gw");
        assert_bits_eq(&c0.data, &c1.data, "dense bwd gb");
    }
}
