//! Scoped-thread worker pool for the native kernels.
//!
//! Offline build: no rayon, no crossbeam — workers are `std::thread::scope`
//! threads (stable since 1.63) spawned per parallel region. Kernels hand
//! each worker a *disjoint* `&mut` span of the output, so parallelism can
//! never change any output element's floating-point accumulation order:
//! results are bitwise identical at every thread count. The knob only
//! trades wall-clock for cores.
//!
//! Thread-count precedence (applied by `api::fit` / the kernels):
//! 1. `TrainConfig::native_threads` (explicit config / `--threads` CLI),
//! 2. the `HF_NATIVE_THREADS` environment variable,
//! 3. `std::thread::available_parallelism()` (divided by the rank count
//!    inside `fit`, so ranks don't oversubscribe the machine).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Global worker count. 0 = "not yet resolved" (resolved lazily by
/// [`num_threads`] from the env / machine).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// `HF_NATIVE_THREADS` if set to a positive integer.
pub fn env_threads() -> Option<usize> {
    let v = std::env::var("HF_NATIVE_THREADS").ok()?;
    v.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Current worker count for the native kernels. Resolved on first use:
/// `HF_NATIVE_THREADS` if set, else the machine's available parallelism.
pub fn num_threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = env_threads().unwrap_or_else(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    });
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Set the worker count (clamped to >= 1). Kernels are bitwise
/// deterministic in the thread count, so changing this mid-run only
/// affects speed, never results.
pub fn set_num_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Run `f(chunk_index, chunk)` over consecutive `chunk`-sized pieces of
/// `data` (last piece may be short), spread across [`num_threads`] scoped
/// threads. Chunks are assigned to threads in contiguous runs, but since
/// every chunk is a disjoint `&mut` span and `f` is pure per chunk, the
/// result is identical to the serial loop regardless of thread count.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(data, chunk, num_threads(), f);
}

/// [`par_chunks_mut`] with an explicit thread count. Kernels pass 1 for
/// problems too small to amortize thread spawns (a deterministic,
/// size-only decision — never data- or thread-count-dependent).
pub fn par_chunks_mut_with<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    let nchunks = data.len().div_ceil(chunk);
    if threads <= 1 || nchunks <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    // Contiguous runs of `per` chunks per worker; `chunks_mut` hands each
    // worker a disjoint &mut span with the right lifetime for the scope.
    let per = nchunks.div_ceil(threads);
    let span = per * chunk;
    std::thread::scope(|s| {
        for (t, piece) in data.chunks_mut(span).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (i, c) in piece.chunks_mut(chunk).enumerate() {
                    f(t * per + i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_indices_and_data_match_serial() {
        for threads in [1usize, 2, 3, 4, 7] {
            for len in [0usize, 1, 5, 16, 97, 256] {
                for chunk in [1usize, 3, 16, 300] {
                    let mut data = vec![0u32; len];
                    par_chunks_mut_with(&mut data, chunk, threads, |ci, c| {
                        for (j, v) in c.iter_mut().enumerate() {
                            *v = (ci * chunk + j) as u32;
                        }
                    });
                    let want: Vec<u32> = (0..len as u32).collect();
                    assert_eq!(data, want, "threads={threads} len={len} chunk={chunk}");
                }
            }
        }
    }

    #[test]
    fn short_tail_chunk_is_delivered() {
        let mut data = vec![0u8; 10];
        par_chunks_mut_with(&mut data, 4, 2, |ci, c| {
            if ci == 2 {
                assert_eq!(c.len(), 2);
            } else {
                assert_eq!(c.len(), 4);
            }
            c.fill(ci as u8 + 1);
        });
        assert_eq!(data, vec![1, 1, 1, 1, 2, 2, 2, 2, 3, 3]);
    }

    #[test]
    fn thread_count_roundtrip() {
        // The only test in this binary that asserts the global's value
        // (other tests may set it, but none read it back).
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0); // clamps to 1
        assert_eq!(num_threads(), 1);
        set_num_threads(1);
    }
}
