//! Native CPU executor for the primitive catalog.
//!
//! The original runtime loaded AOT-compiled HLO artifacts through the PJRT
//! C API (`xla` crate). That crate (and the compiled artifacts) are not
//! available in the offline build, so this module implements the *same
//! primitive contract* — `python/compile/model.py`'s instance grammar,
//! argument order and output order — directly in Rust. The artifact *names*
//! stay the interchange format: `dense_n2_d4_m3.fwd` executes the dense
//! forward for (n=2, d=4, m=3) whether it is backed by an HLO file or by
//! this executor.
//!
//! Every kernel is deterministic (fixed accumulation order), which is what
//! the sequential-vs-parallel bitwise-equivalence tests rely on: every rank
//! and the sequential baseline run the exact same f32 operations in the
//! exact same order. The hot paths (conv/dense, i.e. matmul + im2col)
//! delegate to the blocked, multi-threaded kernels in [`super::kernels`],
//! which are bitwise identical to the scalar references at any thread
//! count — see that module's determinism contract.
//!
//! Math follows `python/compile/kernels/ref.py`:
//! - conv2d: SAME padding, NCHW/OIHW, via im2col + matmul (and the
//!   transposed matmuls + col2im scatter for backward),
//! - batchnorm: train-mode batch statistics, eps 1e-5, closed-form VJP,
//! - softmax cross-entropy: stable logsumexp, mean loss, glogits
//!   `(softmax - y)/n`.

use super::kernels::{conv2d_bwd, conv2d_fwd, dense_bwd, dense_fwd};
use super::manifest::ArtifactMeta;
use crate::tensor::{Shape, Tensor};

const BN_EPS: f32 = 1e-5;

/// Primitive kinds of the catalog (shared with python/compile/model.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimKind {
    Conv3x3,
    Conv1x1,
    ConvBnRelu,
    Bn,
    Relu4,
    Relu2,
    MaxPool2,
    Gap,
    Dense,
    DenseRelu,
    SoftmaxXent,
}

/// A parsed artifact name: primitive + instance parameters + direction.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub prim: PrimKind,
    /// (n, c, k, h, w, s) for convs; (n, c, h, w) for bn/relu4/pool/gap;
    /// (n, d, m) for dense; (n, d) for relu2; (n, c) for softmaxxent.
    /// Unused slots stay 0.
    pub n: usize,
    pub c: usize,
    pub k: usize,
    pub h: usize,
    pub w: usize,
    pub s: usize,
    pub d: usize,
    pub m: usize,
    pub bwd: bool,
}

/// Parse `conv3x3_n8_c16_k16_h32_w32_s1.fwd`-style names. Returns `None`
/// for names outside the catalog (the caller reports "not in manifest").
pub fn parse_name(name: &str) -> Option<Plan> {
    let (base, bwd) = if let Some(b) = name.strip_suffix(".fwd") {
        (b, false)
    } else if let Some(b) = name.strip_suffix(".bwd") {
        (b, true)
    } else {
        return None;
    };
    let mut parts = base.split('_');
    let prim = match parts.next()? {
        "conv3x3" => PrimKind::Conv3x3,
        "conv1x1" => PrimKind::Conv1x1,
        "convbnrelu" => PrimKind::ConvBnRelu,
        "bn" => PrimKind::Bn,
        "relu4" => PrimKind::Relu4,
        "relu2" => PrimKind::Relu2,
        "maxpool2" => PrimKind::MaxPool2,
        "gap" => PrimKind::Gap,
        "dense" => PrimKind::Dense,
        "denserelu" => PrimKind::DenseRelu,
        "softmaxxent" => PrimKind::SoftmaxXent,
        _ => return None,
    };
    if bwd && prim == PrimKind::SoftmaxXent {
        return None; // loss has no separate bwd artifact
    }
    let mut plan = Plan {
        prim, n: 0, c: 0, k: 0, h: 0, w: 0, s: 0, d: 0, m: 0, bwd,
    };
    let order: &[char] = match prim {
        PrimKind::Conv3x3 | PrimKind::Conv1x1 | PrimKind::ConvBnRelu => {
            &['n', 'c', 'k', 'h', 'w', 's']
        }
        PrimKind::Bn | PrimKind::Relu4 | PrimKind::MaxPool2 | PrimKind::Gap => {
            &['n', 'c', 'h', 'w']
        }
        PrimKind::Dense | PrimKind::DenseRelu => &['n', 'd', 'm'],
        PrimKind::Relu2 => &['n', 'd'],
        PrimKind::SoftmaxXent => &['n', 'c'],
    };
    for &key in order {
        let tok = parts.next()?;
        if tok.len() < 2 || !tok.is_ascii() {
            return None;
        }
        let (tk, tv) = tok.split_at(1);
        if tk.chars().next()? != key {
            return None;
        }
        let v: usize = tv.parse().ok()?;
        match key {
            'n' => plan.n = v,
            'c' => plan.c = v,
            'k' => plan.k = v,
            'h' => plan.h = v,
            'w' => plan.w = v,
            's' => plan.s = v,
            'd' => plan.d = v,
            'm' => plan.m = v,
            _ => unreachable!(),
        }
    }
    if parts.next().is_some() {
        return None;
    }
    Some(plan)
}

fn shp(dims: &[usize]) -> Shape {
    Shape::new(dims)
}

/// Input/output shapes of a plan — the synthesized manifest entry
/// (identical to what `python/compile/aot.py` would have written).
pub fn meta_of(name: &str, p: &Plan) -> ArtifactMeta {
    let (ins, outs): (Vec<Shape>, Vec<Shape>) = match p.prim {
        PrimKind::Conv3x3 | PrimKind::Conv1x1 => {
            let kk = if p.prim == PrimKind::Conv3x3 { 3 } else { 1 };
            let (ho, wo) = (p.h.div_ceil(p.s), p.w.div_ceil(p.s));
            let x = shp(&[p.n, p.c, p.h, p.w]);
            let w = shp(&[p.k, p.c, kk, kk]);
            let gy = shp(&[p.n, p.k, ho, wo]);
            if p.bwd {
                (vec![x.clone(), w.clone(), gy], vec![x, w])
            } else {
                (vec![x, w], vec![gy])
            }
        }
        PrimKind::ConvBnRelu => {
            let (ho, wo) = (p.h.div_ceil(p.s), p.w.div_ceil(p.s));
            let x = shp(&[p.n, p.c, p.h, p.w]);
            let w = shp(&[p.k, p.c, 3, 3]);
            let g = shp(&[p.k]);
            let y = shp(&[p.n, p.k, ho, wo]);
            if p.bwd {
                (
                    vec![x.clone(), w.clone(), g.clone(), g.clone(), y],
                    vec![x, w, g.clone(), g],
                )
            } else {
                (vec![x, w, g.clone(), g], vec![y])
            }
        }
        PrimKind::Bn => {
            let x = shp(&[p.n, p.c, p.h, p.w]);
            let g = shp(&[p.c]);
            if p.bwd {
                (vec![x.clone(), g.clone(), x.clone()], vec![x, g.clone(), g])
            } else {
                (vec![x.clone(), g.clone(), g], vec![x])
            }
        }
        PrimKind::Relu4 => {
            let x = shp(&[p.n, p.c, p.h, p.w]);
            if p.bwd {
                (vec![x.clone(), x.clone()], vec![x])
            } else {
                (vec![x.clone()], vec![x])
            }
        }
        PrimKind::Relu2 => {
            let x = shp(&[p.n, p.d]);
            if p.bwd {
                (vec![x.clone(), x.clone()], vec![x])
            } else {
                (vec![x.clone()], vec![x])
            }
        }
        PrimKind::MaxPool2 => {
            let x = shp(&[p.n, p.c, p.h, p.w]);
            let y = shp(&[p.n, p.c, p.h / 2, p.w / 2]);
            if p.bwd {
                (vec![x.clone(), y], vec![x])
            } else {
                (vec![x], vec![y])
            }
        }
        PrimKind::Gap => {
            let x = shp(&[p.n, p.c, p.h, p.w]);
            let y = shp(&[p.n, p.c]);
            if p.bwd {
                (vec![y], vec![x])
            } else {
                (vec![x], vec![y])
            }
        }
        PrimKind::Dense | PrimKind::DenseRelu => {
            let x = shp(&[p.n, p.d]);
            let w = shp(&[p.d, p.m]);
            let b = shp(&[p.m]);
            let y = shp(&[p.n, p.m]);
            if p.bwd {
                if p.prim == PrimKind::DenseRelu {
                    (vec![x.clone(), w.clone(), b.clone(), y], vec![x, w, b])
                } else {
                    (vec![x.clone(), w.clone(), y], vec![x, w, b])
                }
            } else {
                (vec![x, w, b], vec![y])
            }
        }
        PrimKind::SoftmaxXent => {
            let l = shp(&[p.n, p.c]);
            (vec![l.clone(), l.clone()], vec![shp(&[]), l])
        }
    };
    ArtifactMeta { name: name.to_string(), in_shapes: ins, out_shapes: outs }
}

/// Execute a plan on host tensors. Shapes were validated by the caller.
pub fn execute(p: &Plan, args: &[&Tensor]) -> Vec<Tensor> {
    match (p.prim, p.bwd) {
        (PrimKind::Conv3x3, false) => vec![conv2d_fwd(args[0], args[1], 3, p.s)],
        (PrimKind::Conv1x1, false) => vec![conv2d_fwd(args[0], args[1], 1, p.s)],
        (PrimKind::Conv3x3, true) => {
            let (gx, gw) = conv2d_bwd(args[0], args[1], args[2], 3, p.s);
            vec![gx, gw]
        }
        (PrimKind::Conv1x1, true) => {
            let (gx, gw) = conv2d_bwd(args[0], args[1], args[2], 1, p.s);
            vec![gx, gw]
        }
        (PrimKind::ConvBnRelu, false) => {
            let y = conv2d_fwd(args[0], args[1], 3, p.s);
            let z = bn_fwd(&y, args[2], args[3]);
            vec![relu_fwd(&z)]
        }
        (PrimKind::ConvBnRelu, true) => {
            // Recompute y and z from (x, w, gamma, beta), chain the bwds.
            let (x, w, gamma, _beta, gy) = (args[0], args[1], args[2], args[3], args[4]);
            let y = conv2d_fwd(x, w, 3, p.s);
            let z = bn_fwd(&y, gamma, args[3]);
            let gz = relu_bwd(&z, gy);
            let (gyy, ggamma, gbeta) = bn_bwd(&y, gamma, &gz);
            let (gx, gw) = conv2d_bwd(x, w, &gyy, 3, p.s);
            vec![gx, gw, ggamma, gbeta]
        }
        (PrimKind::Bn, false) => vec![bn_fwd(args[0], args[1], args[2])],
        (PrimKind::Bn, true) => {
            let (gx, gg, gb) = bn_bwd(args[0], args[1], args[2]);
            vec![gx, gg, gb]
        }
        (PrimKind::Relu4, false) | (PrimKind::Relu2, false) => vec![relu_fwd(args[0])],
        (PrimKind::Relu4, true) | (PrimKind::Relu2, true) => {
            vec![relu_bwd(args[0], args[1])]
        }
        (PrimKind::MaxPool2, false) => vec![maxpool2_fwd(args[0])],
        (PrimKind::MaxPool2, true) => vec![maxpool2_bwd(args[0], args[1])],
        (PrimKind::Gap, false) => vec![gap_fwd(args[0])],
        (PrimKind::Gap, true) => vec![gap_bwd(args[0], p.h, p.w)],
        (PrimKind::Dense, false) => vec![dense_fwd(args[0], args[1], args[2], false)],
        (PrimKind::DenseRelu, false) => vec![dense_fwd(args[0], args[1], args[2], true)],
        (PrimKind::Dense, true) => {
            let (gx, gw, gb) = dense_bwd(args[0], args[1], args[2]);
            vec![gx, gw, gb]
        }
        (PrimKind::DenseRelu, true) => {
            // Recompute the pre-activation mask, then plain dense backward.
            let (x, w, b, gy) = (args[0], args[1], args[2], args[3]);
            let y = dense_fwd(x, w, b, false);
            let g = relu_bwd(&y, gy);
            let (gx, gw, gb) = dense_bwd(x, w, &g);
            vec![gx, gw, gb]
        }
        (PrimKind::SoftmaxXent, false) => {
            let (loss, glogits) = softmax_xent(args[0], args[1]);
            vec![loss, glogits]
        }
        (PrimKind::SoftmaxXent, true) => unreachable!("softmaxxent has no bwd"),
    }
}

// ---------------------------------------------------------------------------
// batchnorm (train mode, batch statistics over N, H, W per channel)
// ---------------------------------------------------------------------------

/// Per-channel (mean, inverse std) of a [N,C,H,W] tensor.
fn bn_stats(x: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let m = (n * h * w) as f32;
    let mut mean = vec![0.0f32; c];
    let mut istd = vec![0.0f32; c];
    for ci in 0..c {
        let mut sum = 0.0f32;
        for nn in 0..n {
            let base = ((nn * c + ci) * h) * w;
            for v in &x.data[base..base + h * w] {
                sum += v;
            }
        }
        let mu = sum / m;
        let mut var = 0.0f32;
        for nn in 0..n {
            let base = ((nn * c + ci) * h) * w;
            for v in &x.data[base..base + h * w] {
                let dv = v - mu;
                var += dv * dv;
            }
        }
        mean[ci] = mu;
        istd[ci] = 1.0 / (var / m + BN_EPS).sqrt();
    }
    (mean, istd)
}

fn bn_fwd(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> Tensor {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let (mean, istd) = bn_stats(x);
    let mut y = vec![0.0f32; x.numel()];
    for nn in 0..n {
        for ci in 0..c {
            let base = ((nn * c + ci) * h) * w;
            let (mu, is, g, b) = (mean[ci], istd[ci], gamma.data[ci], beta.data[ci]);
            for i in base..base + h * w {
                y[i] = (x.data[i] - mu) * is * g + b;
            }
        }
    }
    Tensor::new(x.shape.clone(), y)
}

/// Closed-form train-mode BN backward: (gx, ggamma, gbeta).
fn bn_bwd(x: &Tensor, gamma: &Tensor, gy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let m = (n * h * w) as f32;
    let (mean, istd) = bn_stats(x);
    let mut ggamma = vec![0.0f32; c];
    let mut gbeta = vec![0.0f32; c];
    let mut gx = vec![0.0f32; x.numel()];
    for ci in 0..c {
        let (mu, is, g) = (mean[ci], istd[ci], gamma.data[ci]);
        // First pass: sum(gy) and sum(gy * xhat) for the channel.
        let (mut sg, mut sgx) = (0.0f32, 0.0f32);
        for nn in 0..n {
            let base = ((nn * c + ci) * h) * w;
            for i in base..base + h * w {
                let xhat = (x.data[i] - mu) * is;
                sg += gy.data[i];
                sgx += gy.data[i] * xhat;
            }
        }
        gbeta[ci] = sg;
        ggamma[ci] = sgx;
        // gx = (gamma * istd / m) * (m*gy - sum(gy) - xhat * sum(gy*xhat))
        let scale = g * is / m;
        for nn in 0..n {
            let base = ((nn * c + ci) * h) * w;
            for i in base..base + h * w {
                let xhat = (x.data[i] - mu) * is;
                gx[i] = scale * (m * gy.data[i] - sg - xhat * sgx);
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(Shape::new(&[c]), ggamma),
        Tensor::new(Shape::new(&[c]), gbeta),
    )
}

// ---------------------------------------------------------------------------
// relu / maxpool2 / gap
// ---------------------------------------------------------------------------

fn relu_fwd(x: &Tensor) -> Tensor {
    let data = x.data.iter().map(|&v| v.max(0.0)).collect();
    Tensor::new(x.shape.clone(), data)
}

fn relu_bwd(x: &Tensor, gy: &Tensor) -> Tensor {
    let data = x
        .data
        .iter()
        .zip(gy.data.iter())
        .map(|(&v, &g)| if v > 0.0 { g } else { 0.0 })
        .collect();
    Tensor::new(x.shape.clone(), data)
}

fn maxpool2_fwd(x: &Tensor) -> Tensor {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let (ho, wo) = (h / 2, w / 2);
    let mut y = vec![0.0f32; n * c * ho * wo];
    for nc in 0..n * c {
        let xb = nc * h * w;
        let yb = nc * ho * wo;
        for oy in 0..ho {
            for ox in 0..wo {
                let i = xb + (2 * oy) * w + 2 * ox;
                let v = x.data[i]
                    .max(x.data[i + 1])
                    .max(x.data[i + w])
                    .max(x.data[i + w + 1]);
                y[yb + oy * wo + ox] = v;
            }
        }
    }
    Tensor::new(Shape::new(&[n, c, ho, wo]), y)
}

/// Max-pool backward: the gradient flows to the first maximal element of
/// each 2x2 window (deterministic tie-break; ties are measure-zero on the
/// continuous synthetic data).
fn maxpool2_bwd(x: &Tensor, gy: &Tensor) -> Tensor {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let (ho, wo) = (h / 2, w / 2);
    let mut gx = vec![0.0f32; x.numel()];
    for nc in 0..n * c {
        let xb = nc * h * w;
        let yb = nc * ho * wo;
        for oy in 0..ho {
            for ox in 0..wo {
                let i = xb + (2 * oy) * w + 2 * ox;
                let idxs = [i, i + 1, i + w, i + w + 1];
                let mut best = idxs[0];
                for &j in &idxs[1..] {
                    if x.data[j] > x.data[best] {
                        best = j;
                    }
                }
                gx[best] += gy.data[yb + oy * wo + ox];
            }
        }
    }
    Tensor::new(x.shape.clone(), gx)
}

fn gap_fwd(x: &Tensor) -> Tensor {
    let d = x.shape.dims();
    let (n, c, h, w) = (d[0], d[1], d[2], d[3]);
    let hw = (h * w) as f32;
    let mut y = vec![0.0f32; n * c];
    for nc in 0..n * c {
        let mut sum = 0.0f32;
        for v in &x.data[nc * h * w..(nc + 1) * h * w] {
            sum += v;
        }
        y[nc] = sum / hw;
    }
    Tensor::new(Shape::new(&[n, c]), y)
}

fn gap_bwd(gy: &Tensor, h: usize, w: usize) -> Tensor {
    let d = gy.shape.dims();
    let (n, c) = (d[0], d[1]);
    let hw = (h * w) as f32;
    let mut gx = vec![0.0f32; n * c * h * w];
    for nc in 0..n * c {
        let g = gy.data[nc] / hw;
        for v in &mut gx[nc * h * w..(nc + 1) * h * w] {
            *v = g;
        }
    }
    Tensor::new(Shape::new(&[n, c, h, w]), gx)
}

// ---------------------------------------------------------------------------
// softmax cross-entropy (dense/conv live in `super::kernels`)
// ---------------------------------------------------------------------------

/// Mean softmax cross-entropy: (scalar loss, dloss/dlogits).
fn softmax_xent(logits: &Tensor, y_onehot: &Tensor) -> (Tensor, Tensor) {
    let (n, c) = (logits.shape.dims()[0], logits.shape.dims()[1]);
    let mut glogits = vec![0.0f32; n * c];
    let mut loss = 0.0f32;
    for i in 0..n {
        let row = &logits.data[i * c..(i + 1) * c];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let lse = mx + sum.ln();
        for j in 0..c {
            let logp = row[j] - lse;
            let yv = y_onehot.data[i * c + j];
            loss -= yv * logp;
            glogits[i * c + j] = (logp.exp() - yv) / n as f32;
        }
    }
    (
        Tensor::scalar(loss / n as f32),
        Tensor::new(Shape::new(&[n, c]), glogits),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_conv_name() {
        let p = parse_name("conv3x3_n8_c16_k32_h32_w32_s2.bwd").unwrap();
        assert_eq!(p.prim, PrimKind::Conv3x3);
        assert_eq!((p.n, p.c, p.k, p.h, p.w, p.s), (8, 16, 32, 32, 32, 2));
        assert!(p.bwd);
        assert!(parse_name("conv9x9_n1_c1_k1_h1_w1_s1.fwd").is_none());
        assert!(parse_name("softmaxxent_n2_c3.bwd").is_none());
        assert!(parse_name("dense_n2_d4_m3").is_none());
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights reproduces the input.
        let x = Tensor::new(Shape::new(&[1, 2, 2, 2]), (0..8).map(|i| i as f32).collect());
        let mut w = Tensor::zeros(&[2, 2, 1, 1]);
        w.data[0] = 1.0; // out0 <- in0
        w.data[3] = 1.0; // out1 <- in1
        let y = conv2d_fwd(&x, &w, 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_same_padding_sums() {
        // All-ones 3x3 kernel on all-ones input: interior pixels see 9,
        // edges 6, corners 4.
        let x = Tensor::ones(&[1, 1, 3, 3]);
        let w = Tensor::ones(&[1, 1, 3, 3]);
        let y = conv2d_fwd(&x, &w, 3, 1);
        assert_eq!(y.shape.dims(), &[1, 1, 3, 3]);
        assert_eq!(y.data[4], 9.0);
        assert_eq!(y.data[0], 4.0);
        assert_eq!(y.data[1], 6.0);
    }

    #[test]
    fn conv_grad_check() {
        // Finite-difference check of conv2d_bwd on a tiny instance.
        use crate::rng::Rng;
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[2, 2, 4, 4], 1.0, &mut rng);
        let w = Tensor::randn(&[3, 2, 3, 3], 0.5, &mut rng);
        let gy = Tensor::randn(&[2, 3, 4, 4], 1.0, &mut rng);
        let (gx, gw) = conv2d_bwd(&x, &w, &gy, 3, 1);
        let loss = |x: &Tensor, w: &Tensor| -> f32 {
            let y = conv2d_fwd(x, w, 3, 1);
            y.data.iter().zip(gy.data.iter()).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-2f32;
        for &i in &[0usize, 7, 33] {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &w) - loss(&xm, &w)) / (2.0 * eps);
            assert!((num - gx.data[i]).abs() < 2e-2, "gx[{i}]: {num} vs {}", gx.data[i]);
        }
        for &i in &[0usize, 10, 50] {
            let mut wp = w.clone();
            wp.data[i] += eps;
            let mut wm = w.clone();
            wm.data[i] -= eps;
            let num = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps);
            assert!((num - gw.data[i]).abs() < 3e-2, "gw[{i}]: {num} vs {}", gw.data[i]);
        }
    }

    #[test]
    fn bn_normalizes() {
        use crate::rng::Rng;
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[4, 3, 5, 5], 2.5, &mut rng);
        let y = bn_fwd(&x, &Tensor::ones(&[3]), &Tensor::zeros(&[3]));
        // Each channel of the output is ~zero-mean, ~unit-variance.
        let (mean, istd) = bn_stats(&y);
        for c in 0..3 {
            assert!(mean[c].abs() < 1e-4, "mean {}", mean[c]);
            assert!((1.0 / istd[c] - 1.0).abs() < 1e-2, "std {}", 1.0 / istd[c]);
        }
    }

    #[test]
    fn bn_grad_check() {
        use crate::rng::Rng;
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&[2, 2, 3, 3], 1.0, &mut rng);
        let gamma = Tensor::new(Shape::new(&[2]), vec![1.3, 0.7]);
        let gy = Tensor::randn(&[2, 2, 3, 3], 1.0, &mut rng);
        let (gx, gg, gb) = bn_bwd(&x, &gamma, &gy);
        let loss = |x: &Tensor, gamma: &Tensor, beta: &Tensor| -> f32 {
            let y = bn_fwd(x, gamma, beta);
            y.data.iter().zip(gy.data.iter()).map(|(a, b)| a * b).sum()
        };
        let beta = Tensor::zeros(&[2]);
        let eps = 1e-2f32;
        for &i in &[0usize, 5, 17] {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps);
            assert!((num - gx.data[i]).abs() < 2e-2, "gx[{i}]: {num} vs {}", gx.data[i]);
        }
        for i in 0..2 {
            let mut gp = gamma.clone();
            gp.data[i] += eps;
            let mut gm = gamma.clone();
            gm.data[i] -= eps;
            let num = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps);
            assert!((num - gg.data[i]).abs() < 2e-2, "ggamma[{i}]: {num} vs {}", gg.data[i]);
            let mut bp = beta.clone();
            bp.data[i] += eps;
            let mut bm = beta.clone();
            bm.data[i] -= eps;
            let num = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps);
            assert!((num - gb.data[i]).abs() < 2e-2, "gbeta[{i}]: {num} vs {}", gb.data[i]);
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_max() {
        let x = Tensor::new(
            Shape::new(&[1, 1, 2, 2]),
            vec![1.0, 5.0, 3.0, 2.0],
        );
        let y = maxpool2_fwd(&x);
        assert_eq!(y.data, vec![5.0]);
        let gx = maxpool2_bwd(&x, &Tensor::full(&[1, 1, 1, 1], 2.0));
        assert_eq!(gx.data, vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn softmax_xent_uniform() {
        let logits = Tensor::zeros(&[2, 3]);
        let mut y = Tensor::zeros(&[2, 3]);
        y.data[0] = 1.0;
        y.data[5] = 1.0;
        let (loss, g) = softmax_xent(&logits, &y);
        assert!((loss.data[0] - 3f32.ln()).abs() < 1e-6);
        // glogits rows sum to zero.
        let s: f32 = g.data[..3].iter().sum();
        assert!(s.abs() < 1e-6);
    }
}
