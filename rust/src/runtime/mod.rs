//! PJRT runtime: load the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `python/compile/aot.py`) and execute them from the Rust
//! hot path. This is the layer that keeps Python off the training path:
//! after `make artifacts`, the coordinator is self-contained.
//!
//! Per the AOT recipe (see /opt/xla-example/README.md): the interchange
//! format is HLO **text** (`HloModuleProto::from_text_file`); all artifacts
//! were lowered with `return_tuple=True`, so every execution result is a
//! tuple we decompose.
//!
//! One `Runtime` per rank thread (the PJRT wrappers are not `Sync`);
//! executables are compiled lazily on first use and cached, so a rank only
//! pays for the primitives its partition actually runs.

mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use crate::tensor::{Shape, Tensor};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Execution statistics (for the perf pass and benches).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
    pub compiles: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

/// Artifact registry + PJRT client + executable cache for one rank.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.txt`, compiles nothing
    /// yet) and create a PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu failed: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Does the registry hold an artifact of this name?
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        anyhow::ensure!(
            self.manifest.get(name).is_some(),
            "artifact '{name}' not in manifest at {:?} — run `make artifacts` \
             after regenerating the registry (`hyparflow inspect --emit-registry`)",
            self.dir
        );
        let t0 = std::time::Instant::now();
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path not utf-8"),
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        let mut s = self.stats.borrow_mut();
        s.compiles += 1;
        s.compile_secs += t0.elapsed().as_secs_f64();
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (used at startup so the first
    /// training step isn't a compile storm).
    pub fn warmup<'a>(&self, names: impl IntoIterator<Item = &'a str>) -> anyhow::Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` on host tensors, returning host tensors.
    ///
    /// Shapes are validated against the manifest before launch so that a
    /// registry/engine mismatch fails with names, not an XLA shape error.
    pub fn exec(&self, name: &str, args: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        anyhow::ensure!(
            args.len() == meta.in_shapes.len(),
            "{name}: expected {} args, got {}",
            meta.in_shapes.len(),
            args.len()
        );
        for (i, (a, want)) in args.iter().zip(meta.in_shapes.iter()).enumerate() {
            anyhow::ensure!(
                &a.shape == want,
                "{name}: arg {i} shape {} != manifest {}",
                a.shape,
                want
            );
        }
        let exe = self.executable(name)?;
        let t0 = std::time::Instant::now();

        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<anyhow::Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let out_literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
        // All artifacts are lowered with return_tuple=True.
        let parts = out_literal
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == meta.out_shapes.len(),
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            meta.out_shapes.len()
        );
        let outs: Vec<Tensor> = parts
            .iter()
            .zip(meta.out_shapes.iter())
            .map(|(l, shape)| literal_to_tensor(l, shape))
            .collect::<anyhow::Result<_>>()?;

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_secs += t0.elapsed().as_secs_f64();
        s.h2d_bytes += args.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        s.d2h_bytes += outs.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        Ok(outs)
    }
}

fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.rank() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.shape.dims().iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape literal to {}: {e:?}", t.shape))
}

fn literal_to_tensor(l: &xla::Literal, shape: &Shape) -> anyhow::Result<Tensor> {
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))?;
    anyhow::ensure!(
        data.len() == shape.numel(),
        "literal has {} elements, manifest shape {} wants {}",
        data.len(),
        shape,
        shape.numel()
    );
    Ok(Tensor::new(shape.clone(), data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        assert!(
            d.join("manifest.txt").exists(),
            "artifacts not built — run `make artifacts` first"
        );
        d
    }

    #[test]
    fn exec_dense_fwd_matches_cpu_math() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        // dense_n2_d4_m3: y = x @ w + b
        let x = Tensor::new(Shape::new(&[2, 4]), (0..8).map(|i| i as f32).collect());
        let w = Tensor::ones(&[4, 3]);
        let b = Tensor::full(&[3], 0.5);
        let out = rt.exec("dense_n2_d4_m3.fwd", &[&x, &w, &b]).unwrap();
        assert_eq!(out.len(), 1);
        // Row sums: 0+1+2+3=6, 4+5+6+7=22; +0.5.
        assert_eq!(out[0].data, vec![6.5, 6.5, 6.5, 22.5, 22.5, 22.5]);
    }

    #[test]
    fn exec_relu_fwd() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let x = Tensor::new(Shape::new(&[2, 4]),
                            vec![-1., 2., -3., 4., 0., -0.5, 7., -8.]);
        let out = rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap();
        assert_eq!(out[0].data, vec![0., 2., 0., 4., 0., 0., 7., 0.]);
    }

    #[test]
    fn exec_softmaxxent_two_outputs() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let logits = Tensor::zeros(&[2, 3]);
        let mut y = Tensor::zeros(&[2, 3]);
        y.data[0] = 1.0; // class 0
        y.data[5] = 1.0; // class 2
        let out = rt.exec("softmaxxent_n2_c3.fwd", &[&logits, &y]).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].data[0] - (3f32).ln()).abs() < 1e-5, "uniform loss = ln(3)");
        assert_eq!(out[1].shape.dims(), &[2, 3]);
    }

    #[test]
    fn exec_dense_bwd_grads() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let x = Tensor::ones(&[2, 4]);
        let w = Tensor::ones(&[4, 3]);
        let gy = Tensor::ones(&[2, 3]);
        let out = rt.exec("dense_n2_d4_m3.bwd", &[&x, &w, &gy]).unwrap();
        assert_eq!(out.len(), 3); // gx, gw, gb
        assert_eq!(out[0].data, vec![3.0; 8]); // gy @ w^T
        assert_eq!(out[1].data, vec![2.0; 12]); // x^T @ gy
        assert_eq!(out[2].data, vec![2.0; 3]); // col sums of gy
    }

    #[test]
    fn shape_mismatch_is_descriptive() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let bad = Tensor::zeros(&[3, 4]);
        let w = Tensor::ones(&[4, 3]);
        let b = Tensor::zeros(&[3]);
        let err = rt.exec("dense_n2_d4_m3.fwd", &[&bad, &w, &b]).unwrap_err();
        assert!(err.to_string().contains("arg 0 shape"), "err: {err}");
    }

    #[test]
    fn missing_artifact_names_the_fix() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let err = rt.exec("conv9x9_n1_c1_k1_h1_w1_s1.fwd", &[]).unwrap_err();
        assert!(err.to_string().contains("not in manifest"), "err: {err}");
    }

    #[test]
    fn executable_cache_compiles_once() {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let x = Tensor::zeros(&[2, 4]);
        for _ in 0..3 {
            rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap();
        }
        let s = rt.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.executions, 3);
    }
}
