//! Primitive runtime: execute the AOT primitive catalog from the Rust hot
//! path.
//!
//! Historically this layer loaded HLO text artifacts (compiled once by
//! `python/compile/aot.py`) through the PJRT C API. The offline build has
//! neither the PJRT `xla` crate nor the compiled artifacts, so execution is
//! backed by the [`native`] CPU executor, which implements the identical
//! primitive contract (names, argument order, output order — see
//! `python/compile/model.py`). The artifact *name* remains the interface:
//! the engine asks for `conv3x3_n8_c16_k16_h32_w32_s1.fwd` and does not
//! know or care which backend runs it.
//!
//! If `artifacts/manifest.txt` exists (produced by `make artifacts`), it is
//! loaded and used for shape validation — a drift check between the Python
//! registry and the Rust engine. Without it, shapes are synthesized from
//! the artifact name itself ([`native::meta_of`]), so the runtime is fully
//! self-contained.
//!
//! One `Runtime` per rank thread; "compilation" is name parsing + plan
//! caching, counted in [`RuntimeStats`] so the warmup/caching behavior the
//! benches measure is preserved.
//!
//! The hot math lives in [`kernels`] (blocked, register-tiled,
//! multi-threaded matmul/conv/dense — bitwise identical to their scalar
//! references at any thread count) on top of the scoped-thread [`pool`].

pub mod kernels;
mod manifest;
pub mod native;
pub mod pool;

pub use manifest::{ArtifactMeta, Manifest};

use crate::tensor::Tensor;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Execution statistics (for the perf pass and benches).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
    pub compiles: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

/// Artifact registry + plan cache for one rank.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, native::Plan>>,
    stats: RefCell<RuntimeStats>,
    /// hftrace handle recording per-kernel `exec` spans (off by default).
    tracer: RefCell<crate::trace::Tracer>,
}

impl Runtime {
    /// Open the artifact directory. `manifest.txt` is loaded when present
    /// (shape-validation contract with the Python AOT step); otherwise the
    /// runtime synthesizes metadata from artifact names.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.txt");
        let manifest = if mpath.exists() {
            Manifest::load(&mpath)?
        } else {
            Manifest::default()
        };
        Ok(Runtime {
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
            tracer: RefCell::new(crate::trace::Tracer::off()),
        })
    }

    /// Attach an hftrace handle: each `exec` call records a kernel span
    /// (artifact name + output bytes), nested inside the Trainer's compute
    /// IR spans.
    pub fn attach_tracer(&self, tracer: crate::trace::Tracer) {
        *self.tracer.borrow_mut() = tracer;
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Is this name executable (in the manifest or parseable as a catalog
    /// instance)?
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some() || native::parse_name(name).is_some()
    }

    /// Parse-and-cache the execution plan for `name` (the "compile" step).
    fn plan(&self, name: &str) -> anyhow::Result<native::Plan> {
        if let Some(p) = self.cache.borrow().get(name) {
            return Ok(p.clone());
        }
        let t0 = std::time::Instant::now();
        let plan = native::parse_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest at {:?} — not a known primitive \
                 instance; regenerate the registry (`hyparflow inspect --emit-registry`)",
                self.dir
            )
        })?;
        self.cache.borrow_mut().insert(name.to_string(), plan.clone());
        let mut s = self.stats.borrow_mut();
        s.compiles += 1;
        s.compile_secs += t0.elapsed().as_secs_f64();
        Ok(plan)
    }

    /// Eagerly cache a set of artifacts (kept so startup mirrors the old
    /// compile-warmup path; validates every name early).
    pub fn warmup<'a>(&self, names: impl IntoIterator<Item = &'a str>) -> anyhow::Result<()> {
        for n in names {
            self.plan(n)?;
        }
        Ok(())
    }

    /// Execute artifact `name` on host tensors, returning host tensors.
    ///
    /// Shapes are validated against the manifest (if loaded) or the
    /// synthesized metadata before launch, so a registry/engine mismatch
    /// fails with names, not an index error deep in a kernel.
    pub fn exec(&self, name: &str, args: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let plan = self.plan(name)?;
        let meta = match self.manifest.get(name) {
            Some(m) => m.clone(),
            None => native::meta_of(name, &plan),
        };
        anyhow::ensure!(
            args.len() == meta.in_shapes.len(),
            "{name}: expected {} args, got {}",
            meta.in_shapes.len(),
            args.len()
        );
        for (i, (a, want)) in args.iter().zip(meta.in_shapes.iter()).enumerate() {
            anyhow::ensure!(
                &a.shape == want,
                "{name}: arg {i} shape {} != manifest {}",
                a.shape,
                want
            );
        }
        let t0 = std::time::Instant::now();
        let tr = self.tracer.borrow();
        let span = tr.start();
        let outs = native::execute(&plan, args);
        let out_bytes = outs.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        tr.record(span, || {
            crate::trace::Event::span(crate::trace::EventKind::Exec).label(name).bytes(out_bytes)
        });
        drop(tr);
        anyhow::ensure!(
            outs.len() == meta.out_shapes.len(),
            "{name}: got {} outputs, manifest says {}",
            outs.len(),
            meta.out_shapes.len()
        );
        for (o, want) in outs.iter().zip(meta.out_shapes.iter()) {
            anyhow::ensure!(
                &o.shape == want,
                "{name}: output shape {} != manifest {}",
                o.shape,
                want
            );
        }
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_secs += t0.elapsed().as_secs_f64();
        s.h2d_bytes += args.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        s.d2h_bytes += outs.iter().map(|t| t.size_bytes() as u64).sum::<u64>();
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    fn rt() -> Runtime {
        // No artifacts directory needed: the native backend synthesizes
        // metadata from names.
        Runtime::open(std::env::temp_dir().join("hf_no_artifacts")).unwrap()
    }

    #[test]
    fn exec_dense_fwd_matches_cpu_math() {
        let rt = rt();
        // dense_n2_d4_m3: y = x @ w + b
        let x = Tensor::new(Shape::new(&[2, 4]), (0..8).map(|i| i as f32).collect());
        let w = Tensor::ones(&[4, 3]);
        let b = Tensor::full(&[3], 0.5);
        let out = rt.exec("dense_n2_d4_m3.fwd", &[&x, &w, &b]).unwrap();
        assert_eq!(out.len(), 1);
        // Row sums: 0+1+2+3=6, 4+5+6+7=22; +0.5.
        assert_eq!(out[0].data, vec![6.5, 6.5, 6.5, 22.5, 22.5, 22.5]);
    }

    #[test]
    fn exec_relu_fwd() {
        let rt = rt();
        let x = Tensor::new(Shape::new(&[2, 4]),
                            vec![-1., 2., -3., 4., 0., -0.5, 7., -8.]);
        let out = rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap();
        assert_eq!(out[0].data, vec![0., 2., 0., 4., 0., 0., 7., 0.]);
    }

    #[test]
    fn exec_softmaxxent_two_outputs() {
        let rt = rt();
        let logits = Tensor::zeros(&[2, 3]);
        let mut y = Tensor::zeros(&[2, 3]);
        y.data[0] = 1.0; // class 0
        y.data[5] = 1.0; // class 2
        let out = rt.exec("softmaxxent_n2_c3.fwd", &[&logits, &y]).unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0].data[0] - (3f32).ln()).abs() < 1e-5, "uniform loss = ln(3)");
        assert_eq!(out[1].shape.dims(), &[2, 3]);
    }

    #[test]
    fn exec_dense_bwd_grads() {
        let rt = rt();
        let x = Tensor::ones(&[2, 4]);
        let w = Tensor::ones(&[4, 3]);
        let gy = Tensor::ones(&[2, 3]);
        let out = rt.exec("dense_n2_d4_m3.bwd", &[&x, &w, &gy]).unwrap();
        assert_eq!(out.len(), 3); // gx, gw, gb
        assert_eq!(out[0].data, vec![3.0; 8]); // gy @ w^T
        assert_eq!(out[1].data, vec![2.0; 12]); // x^T @ gy
        assert_eq!(out[2].data, vec![2.0; 3]); // col sums of gy
    }

    #[test]
    fn shape_mismatch_is_descriptive() {
        let rt = rt();
        let bad = Tensor::zeros(&[3, 4]);
        let w = Tensor::ones(&[4, 3]);
        let b = Tensor::zeros(&[3]);
        let err = rt.exec("dense_n2_d4_m3.fwd", &[&bad, &w, &b]).unwrap_err();
        assert!(err.to_string().contains("arg 0 shape"), "err: {err}");
    }

    #[test]
    fn missing_artifact_names_the_fix() {
        let rt = rt();
        let err = rt.exec("conv9x9_n1_c1_k1_h1_w1_s1.fwd", &[]).unwrap_err();
        assert!(err.to_string().contains("not in manifest"), "err: {err}");
    }

    #[test]
    fn plan_cache_compiles_once() {
        let rt = rt();
        let x = Tensor::zeros(&[2, 4]);
        for _ in 0..3 {
            rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap();
        }
        let s = rt.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.executions, 3);
    }

    #[test]
    fn manifest_still_validates_when_present() {
        // A manifest entry with wrong shapes must override synthesis and
        // fail the drift check.
        let dir = std::env::temp_dir().join(format!("hf_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# registry-sha256=test\nrelu2_n2_d4.fwd|in=f32[9,9]|out=f32[9,9]\n",
        )
        .unwrap();
        let rt = Runtime::open(&dir).unwrap();
        let x = Tensor::zeros(&[2, 4]);
        let err = rt.exec("relu2_n2_d4.fwd", &[&x]).unwrap_err();
        assert!(err.to_string().contains("arg 0 shape"), "err: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
