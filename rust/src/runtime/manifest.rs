//! Parser for `artifacts/manifest.txt`, the contract file written by
//! `python/compile/aot.py`. Line format (hand-rolled, no serde in the
//! offline build):
//!
//! ```text
//! # registry-sha256=<digest>
//! dense_n2_d4_m3.fwd|in=f32[2,4];f32[4,3];f32[3]|out=f32[2,3]
//! ```

use crate::tensor::Shape;
use std::collections::HashMap;
use std::path::Path;

/// Shapes of one artifact's inputs and outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub in_shapes: Vec<Shape>,
    pub out_shapes: Vec<Shape>,
}

/// The parsed manifest: artifact name -> metadata.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, ArtifactMeta>,
    /// The registry digest stamped by aot.py (freshness check).
    pub registry_digest: Option<String>,
}

fn parse_shape_list(s: &str) -> anyhow::Result<Vec<Shape>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(';').map(parse_typed_shape).collect()
}

/// "f32[2,4]" -> Shape([2,4]); "f32[]" -> scalar.
fn parse_typed_shape(s: &str) -> anyhow::Result<Shape> {
    let s = s.trim();
    let rest = s
        .strip_prefix("f32[")
        .ok_or_else(|| anyhow::anyhow!("expected f32[...], got '{s}' (only f32 supported)"))?;
    let dims = rest
        .strip_suffix(']')
        .ok_or_else(|| anyhow::anyhow!("unterminated shape '{s}'"))?;
    Shape::parse(dims)
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let mut entries = HashMap::new();
        let mut registry_digest = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                if let Some(d) = rest.trim().strip_prefix("registry-sha256=") {
                    registry_digest = Some(d.to_string());
                }
                continue;
            }
            let mut fields = line.split('|');
            let (name, ins, outs) = (|| {
                let name = fields.next()?;
                let ins = fields.next()?.strip_prefix("in=")?;
                let outs = fields.next()?.strip_prefix("out=")?;
                Some((name, ins, outs))
            })()
            .ok_or_else(|| {
                anyhow::anyhow!("manifest line {}: malformed '{line}'", lineno + 1)
            })?;
            let meta = ArtifactMeta {
                name: name.to_string(),
                in_shapes: parse_shape_list(ins)?,
                out_shapes: parse_shape_list(outs)?,
            };
            if entries.insert(name.to_string(), meta).is_some() {
                anyhow::bail!("manifest line {}: duplicate artifact '{name}'", lineno + 1);
            }
        }
        Ok(Manifest { entries, registry_digest })
    }

    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read manifest {path:?}: {e} — run `make artifacts` first"
            )
        })?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(
            "# registry-sha256=abc123\n\
             dense_n2_d4_m3.fwd|in=f32[2,4];f32[4,3];f32[3]|out=f32[2,3]\n\
             softmaxxent_n2_c3.fwd|in=f32[2,3];f32[2,3]|out=f32[];f32[2,3]\n",
        )
        .unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.registry_digest.as_deref(), Some("abc123"));
        let d = m.get("dense_n2_d4_m3.fwd").unwrap();
        assert_eq!(d.in_shapes.len(), 3);
        assert_eq!(d.in_shapes[0], Shape::new(&[2, 4]));
        let s = m.get("softmaxxent_n2_c3.fwd").unwrap();
        assert_eq!(s.out_shapes[0], Shape::new(&[])); // scalar loss
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name-only\n").is_err());
        assert!(Manifest::parse("x|in=f32[2|out=f32[2]\n").is_err());
        assert!(Manifest::parse("x|in=i8[2]|out=f32[2]\n").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let text = "a.fwd|in=f32[1]|out=f32[1]\na.fwd|in=f32[1]|out=f32[1]\n";
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn real_manifest_loads() {
        let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.txt");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.len() >= 10);
            assert!(m.registry_digest.is_some());
        }
    }
}
