//! Regeneration of every table and figure in the paper's evaluation
//! (§7, §8). Each `figNN_*` function returns a printable [`Table`] whose
//! rows mirror what the paper plots; the `rust/benches/` harnesses and the
//! `hyparflow bench` CLI subcommand are thin wrappers around these.
//!
//! Conventions (documented in EXPERIMENTS.md):
//! - Throughput comparisons are at **equal effective batch size** (the
//!   scientifically comparable accounting). Where the paper's own
//!   per-replica-batch accounting changes the picture, the figure notes it.
//! - "best MP" sweeps partitions and microbatch size and reports the best
//!   configuration, matching the paper's "we observed the best performance
//!   when split across k partitions" methodology.
//! - DP baselines sweep replicas-per-node over socket/NUMA granularities
//!   {2, 4, 8}, Horovod-CPU practice (the paper's own runs use 2ppn).

use crate::graph::{zoo, ModelGraph};
use crate::mem;
use crate::partition::Partitioning;
use crate::rng::Rng;
use crate::runtime::{kernels, pool};
use crate::schedule::{ScheduleKind, SendMode};
use crate::sim::{simulate, simulate_sequential, Platform, SimConfig, SimResult};
use crate::util::{json_array, JsonObj, Table};

/// Best model-parallel configuration for a (model, platform, batch) within
/// one node-set: sweeps partitions and microbatch size.
#[allow(clippy::unnecessary_map_or)] // `is_none_or` needs a newer MSRV
pub fn best_mp(
    g: &ModelGraph,
    platform: &Platform,
    nodes: usize,
    parts_options: &[usize],
    batch: usize,
) -> (SimResult, usize, usize) {
    let mut best: Option<(SimResult, usize, usize)> = None;
    for &p in parts_options {
        let Ok(pt) = Partitioning::auto(g, p) else { continue };
        for mb in [1usize, 2, 4, 8] {
            if batch % mb != 0 {
                continue;
            }
            let m = batch / mb;
            let mut cfg = SimConfig::new(platform.clone(), p, 1);
            cfg.nodes = nodes;
            cfg.ppn = p.div_ceil(nodes);
            cfg.microbatch = mb;
            cfg.num_microbatches = m;
            let r = simulate(g, &pt, &cfg);
            if best.as_ref().map_or(true, |(b, _, _)| r.img_per_sec > b.img_per_sec) {
                best = Some((r, p, mb));
            }
        }
    }
    best.expect("at least one MP config")
}

/// Best data-parallel configuration at equal effective batch: sweeps
/// replicas over socket/NUMA granularities.
#[allow(clippy::unnecessary_map_or)] // `is_none_or` needs a newer MSRV
pub fn best_dp(
    g: &ModelGraph,
    platform: &Platform,
    nodes: usize,
    batch: usize,
) -> (SimResult, usize) {
    let pt = Partitioning::auto(g, 1).expect("P=1");
    let mut best: Option<(SimResult, usize)> = None;
    for ppn in [2usize, 4, 8] {
        let r_total = nodes * ppn;
        if batch % r_total != 0 || batch / r_total == 0 {
            continue;
        }
        let mut cfg = SimConfig::new(platform.clone(), 1, r_total);
        cfg.nodes = nodes;
        cfg.ppn = ppn;
        cfg.microbatch = batch / r_total;
        cfg.num_microbatches = 1;
        cfg.overlap_allreduce = false; // plain Horovod baseline
        let r = simulate(g, &pt, &cfg);
        if best.as_ref().map_or(true, |(b, _)| r.img_per_sec > b.img_per_sec) {
            best = Some((r, r_total));
        }
    }
    best.expect("at least one DP config")
}

fn f1(x: f64) -> String {
    format!("{x:.1}")
}

// ---------------------------------------------------------------------------
// Fig 1 — the need for model/hybrid parallelism (memory vs image size)
// ---------------------------------------------------------------------------

pub fn fig01_memory() -> Table {
    let mut t = Table::new(&[
        "model", "image", "mem (GB)", "P100-16G", "V100-32G", "SKX-192G",
    ]);
    let cases: Vec<(&str, usize)> = vec![
        ("resnet110", 224),
        ("resnet110", 720),
        ("resnet1001", 224),
        ("resnet1001", 336),
        ("resnet1001", 720),
        ("resnet5000", 224),
        ("resnet5000", 331),
    ];
    for (name, img) in cases {
        let g = match name {
            "resnet110" => zoo::resnet_v1(110, &[3, img, img], 1000),
            "resnet1001" => zoo::resnet_v2(1001, &[3, img, img], 1000),
            _ => zoo::resnet_v2(4997, &[3, img, img], 1000),
        };
        let e = mem::sequential_memory(&g, 1);
        let mark = |b: f64| if mem::trainable(&e, b) { "yes" } else { "NO" };
        t.row(&[
            name.into(),
            format!("{img}x{img}"),
            format!("{:.1}", e.total_gb()),
            mark(mem::budgets::PASCAL_GB).into(),
            mark(mem::budgets::VOLTA_GB).into(),
            mark(mem::budgets::SKYLAKE_GB).into(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figs 7-10 — single-node seq vs MP vs DP across batch sizes
// ---------------------------------------------------------------------------

fn single_node_sweep(
    g: &ModelGraph,
    platform: &Platform,
    parts_options: &[usize],
    batches: &[usize],
) -> Table {
    let mut t = Table::new(&[
        "BS", "seq img/s", "MP img/s", "(P,mb)", "DP img/s", "(R)", "MP/seq", "MP/DP",
    ]);
    for &bs in batches {
        let seq = simulate_sequential(g, platform, bs);
        let (mp, p, mb) = best_mp(g, platform, 1, parts_options, bs);
        let (dp, r) = best_dp(g, platform, 1, bs);
        t.row(&[
            bs.to_string(),
            f1(seq.img_per_sec),
            f1(mp.img_per_sec),
            format!("({p},{mb})"),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / seq.img_per_sec),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

/// Fig 7: VGG-16, one Skylake node, MP up to 8 partitions.
pub fn fig07_vgg16() -> Table {
    let g = zoo::vgg16(&[3, 32, 32], 10);
    single_node_sweep(&g, &Platform::skylake48(), &[4, 8], &[16, 64, 128, 256, 512, 1024])
}

/// Fig 8: ResNet-110-v1, one Skylake node, MP up to 48 partitions.
pub fn fig08_resnet110() -> Table {
    let g = zoo::resnet110_v1();
    single_node_sweep(
        &g,
        &Platform::skylake48(),
        &[16, 32, 48],
        &[32, 64, 128, 256, 512, 1024],
    )
}

/// Fig 9: ResNet-110-v1 on the AMD platform, MP up to 64 partitions.
pub fn fig09_resnet110_amd() -> Table {
    let g = zoo::resnet110_v1();
    single_node_sweep(
        &g,
        &Platform::epyc64(),
        &[16, 32, 64],
        &[32, 64, 128, 256, 512, 1024],
    )
}

/// Fig 10: ResNet-1001-v2, one Skylake node, MP up to 48 partitions.
pub fn fig10_resnet1001() -> Table {
    let g = zoo::resnet1001_v2();
    single_node_sweep(&g, &Platform::skylake48(), &[24, 48], &[32, 64, 128, 256])
}

// ---------------------------------------------------------------------------
// Figs 11-12 — two-node model-parallel vs data-parallel
// ---------------------------------------------------------------------------

/// Fig 11: VGG-16 across two nodes with 8 model-partitions.
pub fn fig11_vgg16_twonode() -> Table {
    let g = zoo::vgg16(&[3, 32, 32], 10);
    let p = Platform::skylake48();
    let mut t = Table::new(&["BS", "MP-8 img/s", "DP img/s", "(R)", "MP/DP"]);
    for bs in [16usize, 64, 128, 256, 512, 1024] {
        let (mp, _, _) = best_mp(&g, &p, 2, &[8], bs);
        let (dp, r) = best_dp(&g, &p, 2, bs);
        t.row(&[
            bs.to_string(),
            f1(mp.img_per_sec),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

/// Fig 12: ResNet-1001-v2 across two nodes with up to 96 partitions.
pub fn fig12_resnet1001_twonode() -> Table {
    let g = zoo::resnet1001_v2();
    let p = Platform::skylake48();
    let mut t = Table::new(&["BS", "MP img/s", "(P,mb)", "DP img/s", "(R)", "MP/DP"]);
    for bs in [64usize, 128, 256] {
        let (mp, parts, mb) = best_mp(&g, &p, 2, &[48, 96], bs);
        let (dp, r) = best_dp(&g, &p, 2, bs);
        t.row(&[
            bs.to_string(),
            f1(mp.img_per_sec),
            format!("({parts},{mb})"),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 13 — hybrid parallelism at scale (128 nodes)
// ---------------------------------------------------------------------------

/// Fig 13: ResNet-1001-v2 hybrid configurations on up to 128 nodes.
/// Rows: (nodes, replicas, partitions, per-replica batch) -> EBS, img/s,
/// speedup over single-node sequential.
pub fn fig13_hybrid_128nodes() -> Table {
    let g = zoo::resnet1001_v2();
    let p = Platform::skylake48();
    let seq1 = simulate_sequential(&g, &p, 256).img_per_sec;
    let mut t = Table::new(&[
        "nodes", "replicas", "parts", "BS/rep", "EBS", "img/s", "vs 1-node seq",
    ]);
    // (nodes, replicas, partitions, per-replica batch)
    let configs: Vec<(usize, usize, usize, usize)> = vec![
        (1, 1, 48, 256),      // single-node MP
        (2, 2, 48, 256),      // 2 nodes hybrid
        (8, 8, 48, 256),
        (32, 32, 48, 256),
        (128, 128, 48, 256),  // the paper's hybrid flagship: EBS 32768
        (128, 256, 24, 128),  // more replicas, fewer partitions
        (128, 256, 1, 256),   // pure DP at 128 nodes (2ppn)
    ];
    for (nodes, reps, parts, bs) in configs {
        let pt = Partitioning::auto(&g, parts).unwrap();
        let mut cfg = SimConfig::new(p.clone(), parts, reps);
        cfg.nodes = nodes;
        cfg.ppn = (parts * reps).div_ceil(nodes);
        cfg.microbatch = if parts == 1 { bs } else { 1 };
        cfg.num_microbatches = if parts == 1 { 1 } else { bs };
        cfg.overlap_allreduce = parts > 1; // paper §5.3 vs plain Horovod
        let r = simulate(&g, &pt, &cfg);
        t.row(&[
            nodes.to_string(),
            reps.to_string(),
            parts.to_string(),
            bs.to_string(),
            cfg.effective_batch().to_string(),
            f1(r.img_per_sec),
            format!("{:.1}x", r.img_per_sec / seq1),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Schedule comparison — GPipe vs 1F1B on the shared IR
// ---------------------------------------------------------------------------

/// One schedule's row of the schedule comparison (raw values, so the
/// bench harness can emit them as `BENCH_sched.json` while the table
/// formatter renders the human view from the same numbers).
pub struct SchedPoint {
    pub schedule: String,
    pub img_per_sec: f64,
    pub step_secs: f64,
    pub bubble_secs: f64,
    pub bubble_frac: f64,
    pub peak_mem_bytes: u64,
    pub resident_microbatches: usize,
    /// Total post->wait send-window time across ranks (eager transport;
    /// 0 for a program with no `PostSend*`/`WaitSend` pairs).
    pub window_secs: f64,
    /// Window time overlapped with compute, absolute and as a fraction of
    /// the window total — the "communication hidden behind compute" metric.
    pub overlap_secs: f64,
    pub overlap_frac: f64,
}

/// Step time, bubble and peak memory for the same `(model, P, mb, m)` under
/// both schedule generators. All three numbers come from the *same*
/// compiled `schedule::Program` the Trainer would execute: the simulator
/// replays it, the memory model reads its stash live intervals. This is
/// the figure that makes the 1F1B memory win visible: identical compute,
/// identical bubble class, peak activations bounded by pipeline depth
/// instead of `num_microbatches`.
pub fn sched_compare_data(
    g: &ModelGraph,
    platform: &Platform,
    partitions: usize,
    mb: usize,
    num_mb: usize,
) -> Vec<SchedPoint> {
    let mut points = vec![];
    for sched in [
        ScheduleKind::GPipe,
        ScheduleKind::OneF1B,
        ScheduleKind::Interleaved1F1B { v: 2 },
        ScheduleKind::ZbH1,
    ] {
        // Stage-level partitioning per schedule: flat kinds cut the model
        // into `partitions` chunks; interleaved into `partitions * v`,
        // round-robin over the same rank count.
        let pt = sched.partitioning(g, partitions).expect("partitionable");
        let mut cfg = SimConfig::new(platform.clone(), partitions, 1);
        cfg.ppn = partitions;
        cfg.microbatch = mb;
        cfg.num_microbatches = num_mb;
        cfg.schedule = sched;
        // Compile once; the same program object feeds the simulator and
        // the residency column, so the row cannot mix two compilations.
        let prog = crate::schedule::Program::compile(g, &pt, num_mb, sched);
        let b = crate::sim::simulate_program(g, &pt, &cfg, &prog);
        // Overlap comes from the traced replay of the *eager* form of the
        // same program: post->wait windows intersected with compute (the
        // buffered transport makes the step timing identical either way,
        // so these columns describe the same row).
        let eager =
            crate::schedule::Program::compile_with(g, &pt, num_mb, sched, SendMode::Eager);
        let (_, trace) = crate::sim::simulate_program_traced(g, &pt, &cfg, &eager);
        let rep = crate::trace::report::TraceReport::from_trace(&trace);
        points.push(SchedPoint {
            schedule: sched.label(),
            img_per_sec: cfg.effective_batch() as f64 / b.step_secs,
            step_secs: b.step_secs,
            bubble_secs: b.bubble_secs,
            bubble_frac: b.bubble_secs / b.step_secs.max(1e-30),
            peak_mem_bytes: b.mem_bytes,
            resident_microbatches: prog.max_peak_resident_microbatches(),
            window_secs: rep.window_secs,
            overlap_secs: rep.overlap_secs,
            overlap_frac: rep.overlap_frac,
        });
    }
    points
}

/// Render [`sched_compare_data`] points as the comparison table.
pub fn sched_table(points: &[SchedPoint]) -> Table {
    let mut t = Table::new(&[
        "schedule", "img/s", "step (s)", "bubble (s)", "peak mem", "resident mb",
        "bubble frac", "overlap frac",
    ]);
    for p in points {
        t.row(&[
            p.schedule.clone(),
            f1(p.img_per_sec),
            format!("{:.4}", p.step_secs),
            format!("{:.4}", p.bubble_secs),
            crate::util::fmt_bytes(p.peak_mem_bytes),
            p.resident_microbatches.to_string(),
            format!("{:.3}", p.bubble_frac),
            format!("{:.3}", p.overlap_frac),
        ]);
    }
    t
}

/// Table form of the schedule comparison (data + formatting in one call).
pub fn sched_compare(
    g: &ModelGraph,
    platform: &Platform,
    partitions: usize,
    mb: usize,
    num_mb: usize,
) -> Table {
    sched_table(&sched_compare_data(g, platform, partitions, mb, num_mb))
}

/// `BENCH_sched.json` payload for a set of schedule points.
pub fn sched_compare_json(
    model: &str,
    partitions: usize,
    mb: usize,
    num_mb: usize,
    points: &[SchedPoint],
) -> String {
    let rows = json_array(points.iter().map(|p| {
        JsonObj::new()
            .str("schedule", &p.schedule)
            .num("img_per_sec", p.img_per_sec)
            .num("step_secs", p.step_secs)
            .num("bubble_secs", p.bubble_secs)
            .num("bubble_frac", p.bubble_frac)
            .int("peak_mem_bytes", p.peak_mem_bytes)
            .int("resident_microbatches", p.resident_microbatches as u64)
            .num("window_secs", p.window_secs)
            .num("overlap_secs", p.overlap_secs)
            .num("overlap_frac", p.overlap_frac)
            .build()
    }));
    JsonObj::new()
        .str("bench", "sched_compare")
        .str("model", model)
        .int("partitions", partitions as u64)
        .int("microbatch", mb as u64)
        .int("num_microbatches", num_mb as u64)
        .raw("rows", &rows)
        .build()
}

/// Default schedule-comparison scenario: ResNet-110, 4 partitions, deep
/// pipeline (num_microbatches = 4 x partitions).
pub fn fig_sched_memory() -> Table {
    sched_compare(&zoo::resnet110_v1(), &Platform::skylake48(), 4, 4, 16)
}

// ---------------------------------------------------------------------------
// Kernel benchmark — scalar vs blocked GFLOP/s on ResNet layer shapes
// ---------------------------------------------------------------------------

/// One im2col-matmul shape: `[m, k] @ [k, n]` where `m = N*Ho*Wo`,
/// `k = C*kk*kk` (patch features), `n = K` (output channels).
pub struct KernelShape {
    pub name: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// The matmul shapes behind the simulator's cost model: the three
/// conv3x3 stages of ResNet-110 on 32x32 inputs at microbatch 8, plus the
/// flagship 256x2304x256 shape (a 3x3 conv at 256 channels on a 16x16
/// feature map, mb=1) the acceptance criterion tracks across PRs.
pub fn kernel_bench_shapes() -> Vec<KernelShape> {
    vec![
        KernelShape { name: "resnet110 conv3x3 c16 32x32 mb8", m: 8192, k: 144, n: 16 },
        KernelShape { name: "resnet110 conv3x3 c32 16x16 mb8", m: 2048, k: 288, n: 32 },
        KernelShape { name: "resnet110 conv3x3 c64 8x8 mb8", m: 512, k: 576, n: 64 },
        KernelShape { name: "flagship conv3x3 c256 16x16 mb1", m: 256, k: 2304, n: 256 },
    ]
}

/// Measured rates for one shape: the scalar baseline and the blocked
/// kernel at each requested thread count.
pub struct KernelBenchCase {
    pub shape: KernelShape,
    pub flops: f64,
    pub scalar_gflops: f64,
    /// (threads, GFLOP/s) per requested thread count.
    pub blocked_gflops: Vec<(usize, f64)>,
}

impl KernelBenchCase {
    /// Single-thread blocked speedup over the scalar baseline (the
    /// acceptance metric: >= 4x on the flagship shape).
    pub fn speedup_1t(&self) -> f64 {
        self.blocked_gflops
            .iter()
            .find(|p| p.0 == 1)
            .map(|p| p.1 / self.scalar_gflops)
            .unwrap_or(0.0)
    }
}

/// Best-of-3 wall time per call for a closure (after one warmup call).
fn time_best(reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup: page in buffers, settle the branch predictors
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

/// Measure scalar-vs-blocked matmul GFLOP/s over [`kernel_bench_shapes`]
/// at each thread count in `thread_counts`. Restores the pool's previous
/// thread setting before returning.
pub fn kernel_bench(thread_counts: &[usize]) -> Vec<KernelBenchCase> {
    let prev = pool::num_threads();
    let mut cases = vec![];
    for shape in kernel_bench_shapes() {
        let (m, k, n) = (shape.m, shape.k, shape.n);
        let mut rng = Rng::new(0x6b65726e);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let flops = 2.0 * (m * k * n) as f64;
        // Aim each timing loop at ~0.2 GFLOP of work so small shapes get
        // enough reps to be measurable without stalling large ones.
        let reps = ((2e8 / flops).ceil() as usize).clamp(1, 64);
        pool::set_num_threads(1);
        let dt = time_best(reps, || {
            let _ = kernels::scalar::matmul(&a, &b, m, k, n);
        });
        let scalar_gflops = flops / dt / 1e9;
        let mut blocked_gflops = vec![];
        for &t in thread_counts {
            pool::set_num_threads(t);
            let dt = time_best(reps, || {
                let _ = kernels::matmul(&a, &b, m, k, n);
            });
            blocked_gflops.push((t, flops / dt / 1e9));
        }
        cases.push(KernelBenchCase { shape, flops, scalar_gflops, blocked_gflops });
    }
    pool::set_num_threads(prev);
    cases
}

/// Render kernel-bench cases as a table (one speedup column per measured
/// thread count).
pub fn kernel_bench_table(cases: &[KernelBenchCase]) -> Table {
    let mut headers: Vec<String> = vec!["shape".into(), "m x k x n".into(), "scalar GF/s".into()];
    if let Some(first) = cases.first() {
        for (t, _) in &first.blocked_gflops {
            headers.push(format!("blocked@{t}T"));
        }
    }
    headers.push("1T speedup".into());
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for c in cases {
        let mut row = vec![
            c.shape.name.to_string(),
            format!("{}x{}x{}", c.shape.m, c.shape.k, c.shape.n),
            f1(c.scalar_gflops),
        ];
        for (_, gf) in &c.blocked_gflops {
            row.push(f1(*gf));
        }
        row.push(format!("{:.2}x", c.speedup_1t()));
        t.row(&row);
    }
    t
}

/// `BENCH_kernels.json` payload: GFLOP/s per shape per thread count, the
/// SIMD backend in use, and the machine's available parallelism (so a
/// 1-core CI runner's flat scaling curve is interpretable).
pub fn kernel_bench_json(cases: &[KernelBenchCase]) -> String {
    let threads_available =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let cases_json = json_array(cases.iter().map(|c| {
        let blocked = json_array(c.blocked_gflops.iter().map(|(t, gf)| {
            JsonObj::new().int("threads", *t as u64).num("gflops", *gf).build()
        }));
        JsonObj::new()
            .str("name", c.shape.name)
            .int("m", c.shape.m as u64)
            .int("k", c.shape.k as u64)
            .int("n", c.shape.n as u64)
            .num("flops", c.flops)
            .num("scalar_gflops", c.scalar_gflops)
            .raw("blocked", &blocked)
            .num("speedup_1t", c.speedup_1t())
            .build()
    }));
    JsonObj::new()
        .str("bench", "kernels")
        .str("simd", kernels::simd_backend())
        .int("threads_available", threads_available as u64)
        .raw("cases", &cases_json)
        .build()
}

// ---------------------------------------------------------------------------
// Cost-model calibration — measure this host's kernels for the simulator
// ---------------------------------------------------------------------------

/// Measure the dispatch floor and sustained conv rate of the native
/// executor on this host and return the calibration table text consumed by
/// `sim::CostModel::apply_calibration` (`hyparflow calibrate`, and
/// `hyparflow sim --calibrate` which feeds it straight into the run).
pub fn measure_calibration() -> anyhow::Result<String> {
    use crate::runtime::Runtime;
    use crate::tensor::Tensor;
    let rt = Runtime::open(crate::api::default_artifacts_dir())?;

    // Dispatch floor: tiny op, many reps.
    let x = Tensor::zeros(&[2, 4]);
    rt.exec("relu2_n2_d4.fwd", &[&x])?;
    let t0 = std::time::Instant::now();
    let n = 300;
    for _ in 0..n {
        rt.exec("relu2_n2_d4.fwd", &[&x])?;
    }
    let dispatch = t0.elapsed().as_secs_f64() / n as f64;

    // Sustained rate from the ResNet workhorse conv (mb=8).
    let cx = Tensor::zeros(&[8, 16, 32, 32]);
    let cw = Tensor::zeros(&[16, 16, 3, 3]);
    let flops = 2.0 * 16.0 * 16.0 * 9.0 * 32.0 * 32.0 * 8.0;
    rt.exec("conv3x3_n8_c16_k16_h32_w32_s1.fwd", &[&cx, &cw])?;
    let t0 = std::time::Instant::now();
    let n = 30;
    for _ in 0..n {
        rt.exec("conv3x3_n8_c16_k16_h32_w32_s1.fwd", &[&cx, &cw])?;
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    let core_rate = flops / (per - dispatch).max(1e-9);

    Ok(format!(
        "# hyparflow calibration (native-kernel measurements on this host)\n\
         # dispatch: tiny-op round trip; core_rate: conv3x3 16ch mb8\n\
         dispatch {dispatch:.6e}\ncore_rate {core_rate:.6e}\n"
    ))
}

// ---------------------------------------------------------------------------
// Table 3 — ResNet-5000 trainability at 331x331
// ---------------------------------------------------------------------------

pub fn table3_resnet5k() -> Table {
    let g = zoo::resnet5000();
    let budget = mem::budgets::SKYLAKE_GB;
    let mut t = Table::new(&["batch", "Sequential", "HF-MP(2)", "HF-MP(4)", "(GB seq/2/4)"]);
    for bs in [1usize, 2, 4] {
        let seq = mem::sequential_memory(&g, bs);
        let mp2 = mem::mp_memory(&g, 2, bs).unwrap();
        let mp4 = mem::mp_memory(&g, 4, bs).unwrap();
        let mark = |e: &mem::MemEstimate| if mem::trainable(e, budget) { "yes" } else { "NO" };
        t.row(&[
            bs.to_string(),
            mark(&seq).into(),
            mark(&mp2).into(),
            mark(&mp4).into(),
            format!("{:.0}/{:.0}/{:.0}", seq.total_gb(), mp2.total_gb(), mp4.total_gb()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig01_shape() {
        let t = fig01_memory();
        let s = t.to_string();
        assert!(s.contains("resnet1001"));
        // The paper's flagship fact: ResNet-1k @224 doesn't fit a P100.
        let line = s.lines().find(|l| l.contains("resnet1001") && l.contains("224")).unwrap();
        assert!(line.contains("NO"), "{line}");
        assert!(line.contains("yes"), "{line}");
    }

    #[test]
    fn fig08_mp_beats_seq_everywhere() {
        let t = fig08_resnet110();
        let s = t.to_string();
        for line in s.lines().skip(2) {
            // "MP/seq" column: must be > 1 for all batch sizes.
            let cols: Vec<&str> = line.split('|').map(str::trim).collect();
            let ratio: f64 = cols[7].trim_end_matches('x').parse().unwrap();
            assert!(ratio > 1.0, "MP should beat seq: {line}");
        }
    }

    #[test]
    fn fig10_resnet1001_mp_beats_dp() {
        // Paper's quoted points are at BS=128 (1.75x over DP) and BS=256
        // (2.4x over seq); at the smallest batch our model has MP~DP.
        let t = fig10_resnet1001();
        let s = t.to_string();
        for line in s.lines().skip(2) {
            let cols: Vec<&str> = line.split('|').map(str::trim).collect();
            let bs: usize = cols[1].parse().unwrap();
            let ratio: f64 = cols[8].trim_end_matches('x').parse().unwrap();
            if bs >= 64 {
                assert!(ratio > 1.0, "1001: MP should beat DP at BS>=64: {line}");
            } else {
                assert!(ratio > 0.85, "1001: MP should be near DP at BS=32: {line}");
            }
        }
    }

    #[test]
    fn sched_compare_shows_one_f1b_memory_win() {
        // Acceptance criterion of the schedule-IR refactor: at
        // num_microbatches > num_partitions, 1F1B reports strictly lower
        // peak mem than GPipe in the sim/mem report.
        let t = fig_sched_memory();
        let s = t.to_string();
        let col = |line: &str, i: usize| -> String {
            line.split('|').map(str::trim).nth(i).unwrap().to_string()
        };
        let gp = s.lines().find(|l| col(l, 1) == "gpipe").unwrap().to_string();
        let fb = s.lines().find(|l| col(l, 1) == "1f1b").unwrap().to_string();
        // Resident microbatches: 16 for gpipe, 4 (= P) for 1f1b.
        assert_eq!(col(&gp, 6), "16", "{gp}");
        assert_eq!(col(&fb, 6), "4", "{fb}");
        // And the byte figure is strictly lower (compare via the raw sim).
        let g = zoo::resnet110_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 1);
        cfg.ppn = 4;
        cfg.microbatch = 4;
        cfg.num_microbatches = 16;
        cfg.schedule = ScheduleKind::GPipe;
        let a = simulate(&g, &pt, &cfg).breakdown.mem_bytes;
        cfg.schedule = ScheduleKind::OneF1B;
        let b = simulate(&g, &pt, &cfg).breakdown.mem_bytes;
        assert!(b < a, "1f1b {b} !< gpipe {a}");
    }

    #[test]
    fn kernel_bench_shapes_include_flagship() {
        let shapes = kernel_bench_shapes();
        assert!(
            shapes.iter().any(|s| (s.m, s.k, s.n) == (256, 2304, 256)),
            "the 256x2304x256 acceptance shape must be tracked"
        );
    }

    // Formatting-only checks on hand-built cases: the measuring
    // `kernel_bench` run lives in `cargo bench --bench kernel_bench`
    // (it drives the global thread knob, which unit tests must not).
    fn fake_case() -> KernelBenchCase {
        KernelBenchCase {
            shape: KernelShape { name: "flagship conv3x3 c256 16x16 mb1", m: 256, k: 2304, n: 256 },
            flops: 2.0 * 256.0 * 2304.0 * 256.0,
            scalar_gflops: 2.0,
            blocked_gflops: vec![(1, 9.0), (2, 17.0), (4, 33.0)],
        }
    }

    #[test]
    fn kernel_bench_formatting() {
        let cases = [fake_case()];
        assert!((cases[0].speedup_1t() - 4.5).abs() < 1e-12);
        let s = kernel_bench_table(&cases).to_string();
        assert!(s.contains("blocked@4T"), "{s}");
        assert!(s.contains("4.50x"), "{s}");
        let j = kernel_bench_json(&cases);
        assert!(j.contains("\"bench\":\"kernels\""), "{j}");
        assert!(j.contains("\"m\":256"), "{j}");
        assert!(j.contains("\"threads\":4"), "{j}");
        assert!(j.contains("\"speedup_1t\":4.5"), "{j}");
    }

    #[test]
    fn sched_json_has_expected_keys() {
        let pts = sched_compare_data(&zoo::resnet110_v1(), &Platform::skylake48(), 4, 4, 16);
        let j = sched_compare_json("resnet110", 4, 4, 16, &pts);
        for key in [
            "\"bench\":\"sched_compare\"",
            "\"schedule\":\"gpipe\"",
            "\"schedule\":\"1f1b\"",
            "\"schedule\":\"interleaved_1f1b:v=2\"",
            "\"schedule\":\"zb_h1\"",
            "\"bubble_frac\"",
            "\"peak_mem_bytes\"",
            "\"resident_microbatches\"",
            "\"window_secs\"",
            "\"overlap_secs\"",
            "\"overlap_frac\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }

    #[test]
    fn sched_overlap_columns_come_from_eager_send_windows() {
        // The overlap metric measures post->wait windows intersected with
        // compute: every schedule row (traced in eager form) must report
        // open window time and nonzero overlap on the figure scenario,
        // while a blocking-form replay has no windows at all.
        let g = zoo::resnet110_v1();
        let pts = sched_compare_data(&g, &Platform::skylake48(), 4, 4, 16);
        for p in &pts {
            assert!(p.window_secs > 0.0, "{}: no send windows", p.schedule);
            assert!(p.overlap_secs > 0.0, "{}: no overlap", p.schedule);
            assert!(
                (0.0..=1.0).contains(&p.overlap_frac),
                "{}: overlap_frac {} out of range",
                p.schedule,
                p.overlap_frac
            );
        }
        // Blocking replay of the same scenario: no post/wait pairs, so the
        // report shows zero window time and a well-defined zero overlap.
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 1);
        cfg.ppn = 4;
        cfg.microbatch = 4;
        cfg.num_microbatches = 16;
        cfg.schedule = ScheduleKind::GPipe;
        let prog = crate::schedule::Program::compile(&g, &pt, 16, ScheduleKind::GPipe);
        let (_, trace) = crate::sim::simulate_program_traced(&g, &pt, &cfg, &prog);
        let rep = crate::trace::report::TraceReport::from_trace(&trace);
        assert_eq!(rep.window_secs, 0.0);
        assert_eq!(rep.overlap_secs, 0.0);
        assert_eq!(rep.overlap_frac, 0.0);
    }

    #[test]
    fn sched_compare_new_rows_cut_the_bubble() {
        // ISSUE 7 acceptance criterion on the figure scenario itself
        // (ResNet-110, P=4, m=16 = 4*depth >= 2*depth): both new schedules
        // report strictly lower bubble fraction than 1F1B.
        let pts = sched_compare_data(&zoo::resnet110_v1(), &Platform::skylake48(), 4, 4, 16);
        let frac = |name: &str| -> f64 {
            pts.iter().find(|p| p.schedule == name).unwrap().bubble_frac
        };
        let f1b = frac("1f1b");
        assert!(
            frac("interleaved_1f1b:v=2") < f1b,
            "interleaved {} !< 1f1b {f1b}",
            frac("interleaved_1f1b:v=2")
        );
        assert!(frac("zb_h1") < f1b, "zb_h1 {} !< 1f1b {f1b}", frac("zb_h1"));
    }

    #[test]
    fn table3_mp_enables_larger_batches() {
        let t = table3_resnet5k();
        let s = t.to_string();
        let rows: Vec<&str> = s.lines().skip(2).collect();
        // bs=4: sequential NO, MP(4) yes (paper's Table 3 diagonal).
        assert!(rows[2].contains("NO"), "{}", rows[2]);
        assert!(rows[2].matches("yes").count() >= 1, "{}", rows[2]);
    }

    #[test]
    fn fig13_hybrid_scales_past_100x() {
        let t = fig13_hybrid_128nodes();
        let s = t.to_string();
        let flagship = s
            .lines()
            .find(|l| {
                let c: Vec<&str> = l.split('|').map(str::trim).collect();
                c.len() > 3 && c[1] == "128" && c[3] == "48"
            })
            .unwrap_or_else(|| panic!("no 128-node 48-part row in:\n{s}"));
        let speedup: f64 = flagship
            .split('|')
            .map(str::trim)
            .nth(7)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(
            speedup > 60.0 && speedup < 200.0,
            "hybrid flagship should land near the paper's 110x: {speedup} \n{s}"
        );
    }
}
