//! Regeneration of every table and figure in the paper's evaluation
//! (§7, §8). Each `figNN_*` function returns a printable [`Table`] whose
//! rows mirror what the paper plots; the `rust/benches/` harnesses and the
//! `hyparflow bench` CLI subcommand are thin wrappers around these.
//!
//! Conventions (documented in EXPERIMENTS.md):
//! - Throughput comparisons are at **equal effective batch size** (the
//!   scientifically comparable accounting). Where the paper's own
//!   per-replica-batch accounting changes the picture, the figure notes it.
//! - "best MP" sweeps partitions and microbatch size and reports the best
//!   configuration, matching the paper's "we observed the best performance
//!   when split across k partitions" methodology.
//! - DP baselines sweep replicas-per-node over socket/NUMA granularities
//!   {2, 4, 8}, Horovod-CPU practice (the paper's own runs use 2ppn).

use crate::graph::{zoo, ModelGraph};
use crate::mem;
use crate::partition::Partitioning;
use crate::schedule::ScheduleKind;
use crate::sim::{simulate, simulate_sequential, Platform, SimConfig, SimResult};
use crate::util::Table;

/// Best model-parallel configuration for a (model, platform, batch) within
/// one node-set: sweeps partitions and microbatch size.
pub fn best_mp(
    g: &ModelGraph,
    platform: &Platform,
    nodes: usize,
    parts_options: &[usize],
    batch: usize,
) -> (SimResult, usize, usize) {
    let mut best: Option<(SimResult, usize, usize)> = None;
    for &p in parts_options {
        let Ok(pt) = Partitioning::auto(g, p) else { continue };
        for mb in [1usize, 2, 4, 8] {
            if batch % mb != 0 {
                continue;
            }
            let m = batch / mb;
            let mut cfg = SimConfig::new(platform.clone(), p, 1);
            cfg.nodes = nodes;
            cfg.ppn = p.div_ceil(nodes);
            cfg.microbatch = mb;
            cfg.num_microbatches = m;
            let r = simulate(g, &pt, &cfg);
            if best.as_ref().map_or(true, |(b, _, _)| r.img_per_sec > b.img_per_sec) {
                best = Some((r, p, mb));
            }
        }
    }
    best.expect("at least one MP config")
}

/// Best data-parallel configuration at equal effective batch: sweeps
/// replicas over socket/NUMA granularities.
pub fn best_dp(
    g: &ModelGraph,
    platform: &Platform,
    nodes: usize,
    batch: usize,
) -> (SimResult, usize) {
    let pt = Partitioning::auto(g, 1).expect("P=1");
    let mut best: Option<(SimResult, usize)> = None;
    for ppn in [2usize, 4, 8] {
        let r_total = nodes * ppn;
        if batch % r_total != 0 || batch / r_total == 0 {
            continue;
        }
        let mut cfg = SimConfig::new(platform.clone(), 1, r_total);
        cfg.nodes = nodes;
        cfg.ppn = ppn;
        cfg.microbatch = batch / r_total;
        cfg.num_microbatches = 1;
        cfg.overlap_allreduce = false; // plain Horovod baseline
        let r = simulate(g, &pt, &cfg);
        if best.as_ref().map_or(true, |(b, _)| r.img_per_sec > b.img_per_sec) {
            best = Some((r, r_total));
        }
    }
    best.expect("at least one DP config")
}

fn f1(x: f64) -> String {
    format!("{x:.1}")
}

// ---------------------------------------------------------------------------
// Fig 1 — the need for model/hybrid parallelism (memory vs image size)
// ---------------------------------------------------------------------------

pub fn fig01_memory() -> Table {
    let mut t = Table::new(&[
        "model", "image", "mem (GB)", "P100-16G", "V100-32G", "SKX-192G",
    ]);
    let cases: Vec<(&str, usize)> = vec![
        ("resnet110", 224),
        ("resnet110", 720),
        ("resnet1001", 224),
        ("resnet1001", 336),
        ("resnet1001", 720),
        ("resnet5000", 224),
        ("resnet5000", 331),
    ];
    for (name, img) in cases {
        let g = match name {
            "resnet110" => zoo::resnet_v1(110, &[3, img, img], 1000),
            "resnet1001" => zoo::resnet_v2(1001, &[3, img, img], 1000),
            _ => zoo::resnet_v2(4997, &[3, img, img], 1000),
        };
        let e = mem::sequential_memory(&g, 1);
        let mark = |b: f64| if mem::trainable(&e, b) { "yes" } else { "NO" };
        t.row(&[
            name.into(),
            format!("{img}x{img}"),
            format!("{:.1}", e.total_gb()),
            mark(mem::budgets::PASCAL_GB).into(),
            mark(mem::budgets::VOLTA_GB).into(),
            mark(mem::budgets::SKYLAKE_GB).into(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Figs 7-10 — single-node seq vs MP vs DP across batch sizes
// ---------------------------------------------------------------------------

fn single_node_sweep(
    g: &ModelGraph,
    platform: &Platform,
    parts_options: &[usize],
    batches: &[usize],
) -> Table {
    let mut t = Table::new(&[
        "BS", "seq img/s", "MP img/s", "(P,mb)", "DP img/s", "(R)", "MP/seq", "MP/DP",
    ]);
    for &bs in batches {
        let seq = simulate_sequential(g, platform, bs);
        let (mp, p, mb) = best_mp(g, platform, 1, parts_options, bs);
        let (dp, r) = best_dp(g, platform, 1, bs);
        t.row(&[
            bs.to_string(),
            f1(seq.img_per_sec),
            f1(mp.img_per_sec),
            format!("({p},{mb})"),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / seq.img_per_sec),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

/// Fig 7: VGG-16, one Skylake node, MP up to 8 partitions.
pub fn fig07_vgg16() -> Table {
    let g = zoo::vgg16(&[3, 32, 32], 10);
    single_node_sweep(&g, &Platform::skylake48(), &[4, 8], &[16, 64, 128, 256, 512, 1024])
}

/// Fig 8: ResNet-110-v1, one Skylake node, MP up to 48 partitions.
pub fn fig08_resnet110() -> Table {
    let g = zoo::resnet110_v1();
    single_node_sweep(
        &g,
        &Platform::skylake48(),
        &[16, 32, 48],
        &[32, 64, 128, 256, 512, 1024],
    )
}

/// Fig 9: ResNet-110-v1 on the AMD platform, MP up to 64 partitions.
pub fn fig09_resnet110_amd() -> Table {
    let g = zoo::resnet110_v1();
    single_node_sweep(
        &g,
        &Platform::epyc64(),
        &[16, 32, 64],
        &[32, 64, 128, 256, 512, 1024],
    )
}

/// Fig 10: ResNet-1001-v2, one Skylake node, MP up to 48 partitions.
pub fn fig10_resnet1001() -> Table {
    let g = zoo::resnet1001_v2();
    single_node_sweep(&g, &Platform::skylake48(), &[24, 48], &[32, 64, 128, 256])
}

// ---------------------------------------------------------------------------
// Figs 11-12 — two-node model-parallel vs data-parallel
// ---------------------------------------------------------------------------

/// Fig 11: VGG-16 across two nodes with 8 model-partitions.
pub fn fig11_vgg16_twonode() -> Table {
    let g = zoo::vgg16(&[3, 32, 32], 10);
    let p = Platform::skylake48();
    let mut t = Table::new(&["BS", "MP-8 img/s", "DP img/s", "(R)", "MP/DP"]);
    for bs in [16usize, 64, 128, 256, 512, 1024] {
        let (mp, _, _) = best_mp(&g, &p, 2, &[8], bs);
        let (dp, r) = best_dp(&g, &p, 2, bs);
        t.row(&[
            bs.to_string(),
            f1(mp.img_per_sec),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

/// Fig 12: ResNet-1001-v2 across two nodes with up to 96 partitions.
pub fn fig12_resnet1001_twonode() -> Table {
    let g = zoo::resnet1001_v2();
    let p = Platform::skylake48();
    let mut t = Table::new(&["BS", "MP img/s", "(P,mb)", "DP img/s", "(R)", "MP/DP"]);
    for bs in [64usize, 128, 256] {
        let (mp, parts, mb) = best_mp(&g, &p, 2, &[48, 96], bs);
        let (dp, r) = best_dp(&g, &p, 2, bs);
        t.row(&[
            bs.to_string(),
            f1(mp.img_per_sec),
            format!("({parts},{mb})"),
            f1(dp.img_per_sec),
            format!("({r})"),
            format!("{:.2}x", mp.img_per_sec / dp.img_per_sec),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Fig 13 — hybrid parallelism at scale (128 nodes)
// ---------------------------------------------------------------------------

/// Fig 13: ResNet-1001-v2 hybrid configurations on up to 128 nodes.
/// Rows: (nodes, replicas, partitions, per-replica batch) -> EBS, img/s,
/// speedup over single-node sequential.
pub fn fig13_hybrid_128nodes() -> Table {
    let g = zoo::resnet1001_v2();
    let p = Platform::skylake48();
    let seq1 = simulate_sequential(&g, &p, 256).img_per_sec;
    let mut t = Table::new(&[
        "nodes", "replicas", "parts", "BS/rep", "EBS", "img/s", "vs 1-node seq",
    ]);
    // (nodes, replicas, partitions, per-replica batch)
    let configs: Vec<(usize, usize, usize, usize)> = vec![
        (1, 1, 48, 256),      // single-node MP
        (2, 2, 48, 256),      // 2 nodes hybrid
        (8, 8, 48, 256),
        (32, 32, 48, 256),
        (128, 128, 48, 256),  // the paper's hybrid flagship: EBS 32768
        (128, 256, 24, 128),  // more replicas, fewer partitions
        (128, 256, 1, 256),   // pure DP at 128 nodes (2ppn)
    ];
    for (nodes, reps, parts, bs) in configs {
        let pt = Partitioning::auto(&g, parts).unwrap();
        let mut cfg = SimConfig::new(p.clone(), parts, reps);
        cfg.nodes = nodes;
        cfg.ppn = (parts * reps).div_ceil(nodes);
        cfg.microbatch = if parts == 1 { bs } else { 1 };
        cfg.num_microbatches = if parts == 1 { 1 } else { bs };
        cfg.overlap_allreduce = parts > 1; // paper §5.3 vs plain Horovod
        let r = simulate(&g, &pt, &cfg);
        t.row(&[
            nodes.to_string(),
            reps.to_string(),
            parts.to_string(),
            bs.to_string(),
            cfg.effective_batch().to_string(),
            f1(r.img_per_sec),
            format!("{:.1}x", r.img_per_sec / seq1),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Schedule comparison — GPipe vs 1F1B on the shared IR
// ---------------------------------------------------------------------------

/// Step time, bubble and peak memory for the same `(model, P, mb, m)` under
/// both schedule generators. All three numbers come from the *same*
/// compiled `schedule::Program` the Trainer would execute: the simulator
/// replays it, the memory model reads its stash live intervals. This is
/// the figure that makes the 1F1B memory win visible: identical compute,
/// identical bubble class, peak activations bounded by pipeline depth
/// instead of `num_microbatches`.
pub fn sched_compare(
    g: &ModelGraph,
    platform: &Platform,
    partitions: usize,
    mb: usize,
    num_mb: usize,
) -> Table {
    let pt = Partitioning::auto(g, partitions).expect("partitionable");
    let mut t = Table::new(&[
        "schedule", "img/s", "step (s)", "bubble (s)", "peak mem", "resident mb",
    ]);
    for sched in [ScheduleKind::GPipe, ScheduleKind::OneF1B] {
        let mut cfg = SimConfig::new(platform.clone(), partitions, 1);
        cfg.ppn = partitions;
        cfg.microbatch = mb;
        cfg.num_microbatches = num_mb;
        cfg.schedule = sched;
        // Compile once; the same program object feeds the simulator and
        // the residency column, so the row cannot mix two compilations.
        let prog = crate::schedule::Program::compile(g, &pt, num_mb, sched);
        let b = crate::sim::simulate_program(g, &pt, &cfg, &prog);
        t.row(&[
            sched.name().into(),
            f1(cfg.effective_batch() as f64 / b.step_secs),
            format!("{:.4}", b.step_secs),
            format!("{:.4}", b.bubble_secs),
            crate::util::fmt_bytes(b.mem_bytes),
            prog.max_peak_resident_microbatches().to_string(),
        ]);
    }
    t
}

/// Default schedule-comparison scenario: ResNet-110, 4 partitions, deep
/// pipeline (num_microbatches = 4 x partitions).
pub fn fig_sched_memory() -> Table {
    sched_compare(&zoo::resnet110_v1(), &Platform::skylake48(), 4, 4, 16)
}

// ---------------------------------------------------------------------------
// Table 3 — ResNet-5000 trainability at 331x331
// ---------------------------------------------------------------------------

pub fn table3_resnet5k() -> Table {
    let g = zoo::resnet5000();
    let budget = mem::budgets::SKYLAKE_GB;
    let mut t = Table::new(&["batch", "Sequential", "HF-MP(2)", "HF-MP(4)", "(GB seq/2/4)"]);
    for bs in [1usize, 2, 4] {
        let seq = mem::sequential_memory(&g, bs);
        let mp2 = mem::mp_memory(&g, 2, bs).unwrap();
        let mp4 = mem::mp_memory(&g, 4, bs).unwrap();
        let mark = |e: &mem::MemEstimate| if mem::trainable(e, budget) { "yes" } else { "NO" };
        t.row(&[
            bs.to_string(),
            mark(&seq).into(),
            mark(&mp2).into(),
            mark(&mp4).into(),
            format!("{:.0}/{:.0}/{:.0}", seq.total_gb(), mp2.total_gb(), mp4.total_gb()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig01_shape() {
        let t = fig01_memory();
        let s = t.to_string();
        assert!(s.contains("resnet1001"));
        // The paper's flagship fact: ResNet-1k @224 doesn't fit a P100.
        let line = s.lines().find(|l| l.contains("resnet1001") && l.contains("224")).unwrap();
        assert!(line.contains("NO"), "{line}");
        assert!(line.contains("yes"), "{line}");
    }

    #[test]
    fn fig08_mp_beats_seq_everywhere() {
        let t = fig08_resnet110();
        let s = t.to_string();
        for line in s.lines().skip(2) {
            // "MP/seq" column: must be > 1 for all batch sizes.
            let cols: Vec<&str> = line.split('|').map(str::trim).collect();
            let ratio: f64 = cols[7].trim_end_matches('x').parse().unwrap();
            assert!(ratio > 1.0, "MP should beat seq: {line}");
        }
    }

    #[test]
    fn fig10_resnet1001_mp_beats_dp() {
        // Paper's quoted points are at BS=128 (1.75x over DP) and BS=256
        // (2.4x over seq); at the smallest batch our model has MP~DP.
        let t = fig10_resnet1001();
        let s = t.to_string();
        for line in s.lines().skip(2) {
            let cols: Vec<&str> = line.split('|').map(str::trim).collect();
            let bs: usize = cols[1].parse().unwrap();
            let ratio: f64 = cols[8].trim_end_matches('x').parse().unwrap();
            if bs >= 64 {
                assert!(ratio > 1.0, "1001: MP should beat DP at BS>=64: {line}");
            } else {
                assert!(ratio > 0.85, "1001: MP should be near DP at BS=32: {line}");
            }
        }
    }

    #[test]
    fn sched_compare_shows_one_f1b_memory_win() {
        // Acceptance criterion of the schedule-IR refactor: at
        // num_microbatches > num_partitions, 1F1B reports strictly lower
        // peak mem than GPipe in the sim/mem report.
        let t = fig_sched_memory();
        let s = t.to_string();
        let col = |line: &str, i: usize| -> String {
            line.split('|').map(str::trim).nth(i).unwrap().to_string()
        };
        let gp = s.lines().find(|l| col(l, 1) == "gpipe").unwrap().to_string();
        let fb = s.lines().find(|l| col(l, 1) == "1f1b").unwrap().to_string();
        // Resident microbatches: 16 for gpipe, 4 (= P) for 1f1b.
        assert_eq!(col(&gp, 6), "16", "{gp}");
        assert_eq!(col(&fb, 6), "4", "{fb}");
        // And the byte figure is strictly lower (compare via the raw sim).
        let g = zoo::resnet110_v1();
        let pt = Partitioning::auto(&g, 4).unwrap();
        let mut cfg = SimConfig::new(Platform::skylake48(), 4, 1);
        cfg.ppn = 4;
        cfg.microbatch = 4;
        cfg.num_microbatches = 16;
        cfg.schedule = ScheduleKind::GPipe;
        let a = simulate(&g, &pt, &cfg).breakdown.mem_bytes;
        cfg.schedule = ScheduleKind::OneF1B;
        let b = simulate(&g, &pt, &cfg).breakdown.mem_bytes;
        assert!(b < a, "1f1b {b} !< gpipe {a}");
    }

    #[test]
    fn table3_mp_enables_larger_batches() {
        let t = table3_resnet5k();
        let s = t.to_string();
        let rows: Vec<&str> = s.lines().skip(2).collect();
        // bs=4: sequential NO, MP(4) yes (paper's Table 3 diagonal).
        assert!(rows[2].contains("NO"), "{}", rows[2]);
        assert!(rows[2].matches("yes").count() >= 1, "{}", rows[2]);
    }

    #[test]
    fn fig13_hybrid_scales_past_100x() {
        let t = fig13_hybrid_128nodes();
        let s = t.to_string();
        let flagship = s
            .lines()
            .find(|l| {
                let c: Vec<&str> = l.split('|').map(str::trim).collect();
                c.len() > 3 && c[1] == "128" && c[3] == "48"
            })
            .unwrap_or_else(|| panic!("no 128-node 48-part row in:\n{s}"));
        let speedup: f64 = flagship
            .split('|')
            .map(str::trim)
            .nth(7)
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(
            speedup > 60.0 && speedup < 200.0,
            "hybrid flagship should land near the paper's 110x: {speedup} \n{s}"
        );
    }
}
