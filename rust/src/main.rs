//! `hyparflow` — the leader CLI.
//!
//! Subcommands:
//!   train      run a training job (the paper's Listing 2, as a CLI)
//!   inspect    print a model summary / partitioning; --emit-registry
//!              regenerates python/compile/registry.txt for `make artifacts`
//!   sim        run the calibrated cluster simulator for a scaling scenario
//!   calibrate  measure per-primitive costs on this host (feeds `sim`)
//!   mem        memory-model report (Fig 1 / Table 3 trainability)
//!
//! Arg parsing is hand-rolled (offline build: no clap). Flags are
//! `--key value`.

use hyparflow::api::{fit, Strategy, TrainConfig};
use hyparflow::graph::{artifact, zoo};
use hyparflow::partition::Partitioning;
use std::collections::BTreeSet;

fn main() {
    // Keep PJRT's TFRT client quiet unless the user overrides.
    if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("sim") => cmd_sim(&args[1..]),
        Some("calibrate") => cmd_calibrate(&args[1..]),
        Some("mem") => cmd_mem(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown subcommand '{other}'")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "hyparflow — hybrid-parallel DNN training (HyPar-Flow reproduction)\n\
         \n\
         USAGE: hyparflow <train|inspect|sim|calibrate|mem> [--key value ...]\n\
         \n\
         train:    --model M --strategy seq|model|data|hybrid --partitions P\n\
         \x20         --replicas R --steps N --mb B --num-mb K\n\
         \x20         --sched gpipe|1f1b|interleaved_1f1b[:v=N]|zb_h1\n\
         \x20         --lr F --seed S --log-every N --eval N --lpp a,b,c\n\
         \x20         --threads T (kernel worker threads; HF_NATIVE_THREADS)\n\
         \x20         --transport buffered|rendezvous (fabric p2p semantics;\n\
         \x20          HF_TRANSPORT)\n\
         \x20         --trace OUT.json (per-rank hftrace -> Chrome JSON; HF_TRACE=1)\n\
         inspect:  --model M [--partitions P] [--emit-registry] [--mb B]\n\
         sim:      --model M --nodes N --ppn P --partitions K --replicas R\n\
         \x20         --mb B --num-mb K --sched gpipe|1f1b|interleaved_1f1b[:v=N]|zb_h1\n\
         \x20         --platform skylake|epyc [--calib FILE] [--trace OUT.json]\n\
         \x20         [--calibrate [--calib-out FILE]]  (measure, then simulate;\n\
         \x20          a .json calib-out round-trips the full cost table)\n\
         calibrate: [--out FILE] [--mb B]\n\
         mem:      --model M [--mb B] [--partitions P]\n\
         \x20         [--num-mb K --sched ...]  (schedule-aware report)"
    );
}

/// Tiny flag parser: --key value pairs + boolean flags.
pub(crate) struct Flags {
    kv: std::collections::HashMap<String, String>,
    bools: BTreeSet<String>,
}

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut kv = std::collections::HashMap::new();
        let mut bools = BTreeSet::new();
        let mut i = 0;
        while i < args.len() {
            let k = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{}'", args[i]))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                kv.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                bools.insert(k.to_string());
                i += 1;
            }
        }
        Ok(Flags { kv, bools })
    }

    fn get<T: std::str::FromStr>(&self, k: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.kv.get(k) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{k} {v}: {e}")),
        }
    }

    fn str(&self, k: &str, default: &str) -> String {
        self.kv.get(k).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, k: &str) -> bool {
        self.bools.contains(k)
    }
}

/// Parse `--sched`. A bare `--sched` (next token is another flag, so the
/// parser filed it as a boolean) must not silently fall back to the
/// default schedule — that's how typos like `--sched --mb 4` used to
/// train GPipe unnoticed. Unknown values hard-error in
/// `ScheduleKind::parse` with the valid list.
fn sched_flag(f: &Flags) -> anyhow::Result<hyparflow::schedule::ScheduleKind> {
    anyhow::ensure!(
        !f.has("sched"),
        "--sched requires a value ({})",
        hyparflow::schedule::VALID_SCHEDULES
    );
    hyparflow::schedule::ScheduleKind::parse(&f.str("sched", "gpipe"))
}

/// Parse `--trace OUT.json`. Like `--sched`, a bare `--trace` must not
/// silently drop the export.
fn trace_flag(f: &Flags) -> anyhow::Result<Option<String>> {
    anyhow::ensure!(
        !f.has("trace"),
        "--trace requires an output path (e.g. --trace trace.json)"
    );
    Ok(f.kv.get("trace").cloned())
}

/// Parse `--transport`. Same strictness as `--sched`: a bare `--transport`
/// hard-errors instead of silently training on the default fabric, and
/// unknown values hard-error in `Transport::parse`. Unflagged runs fall
/// back to `HF_TRANSPORT` (then buffered), matching `TrainConfig::new`.
fn transport_flag(f: &Flags) -> anyhow::Result<hyparflow::hfmpi::Transport> {
    anyhow::ensure!(
        !f.has("transport"),
        "--transport requires a value (buffered|rendezvous)"
    );
    match f.kv.get("transport") {
        Some(v) => hyparflow::hfmpi::Transport::parse(v),
        None => hyparflow::hfmpi::Transport::from_env(),
    }
}

/// Export a finished trace: Chrome JSON to `path` plus the aggregate
/// report on stdout.
fn write_trace(trace: &hyparflow::trace::Trace, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, hyparflow::trace::chrome::chrome_trace_json(trace))?;
    print!("{}", hyparflow::trace::report::TraceReport::from_trace(trace).render());
    println!("wrote {path} (load in Perfetto or chrome://tracing)");
    Ok(())
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let model = zoo::by_name(&f.str("model", "resnet20"))?;
    let strategy = Strategy::parse(&f.str("strategy", "model"))?;
    let mut cfg = TrainConfig::new(model, strategy)
        .partitions(f.get("partitions", 2)?)
        .replicas(f.get("replicas", 1)?)
        .steps(f.get("steps", 20)?)
        .microbatch(f.get("mb", 8)?)
        .num_microbatches(f.get("num-mb", 1)?)
        .schedule(sched_flag(&f)?)
        .transport(transport_flag(&f)?)
        .lr(f.get("lr", 0.05)?)
        .seed(f.get("seed", 42)?)
        .eval_batches(f.get("eval", 0)?)
        .log_every(f.get("log-every", 1)?);
    let trace_out = trace_flag(&f)?;
    if trace_out.is_some() {
        cfg = cfg.trace(true);
    }
    if let Some(lpp) = f.kv.get("lpp") {
        let v: Vec<usize> = lpp
            .split(',')
            .map(|x| x.parse())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("--lpp: {e}"))?;
        cfg = cfg.lpp(v);
    }
    if let Some(t) = f.kv.get("threads") {
        cfg = cfg
            .native_threads(t.parse().map_err(|e| anyhow::anyhow!("--threads {t}: {e}"))?);
    }
    let (p, r) = cfg.effective_topology();
    println!(
        "training {} | strategy={strategy:?} partitions={p} replicas={r} \
         mb={} x {} (per-replica batch {})",
        cfg.model.name,
        cfg.engine.microbatch,
        cfg.engine.num_microbatches,
        cfg.engine.microbatch * cfg.engine.num_microbatches,
    );
    let res = fit(&cfg)?;
    println!(
        "done: final loss={:.4} acc={:.3} | {:.1} img/s over {:.1}s",
        res.final_loss(),
        res.history.last().map(|m| m.accuracy).unwrap_or(0.0),
        res.img_per_sec,
        res.wall_secs
    );
    if let Some(e) = res.eval {
        println!("eval: loss={:.4} acc={:.3}", e.loss, e.accuracy);
    }
    if let Some(path) = trace_out {
        let trace = res
            .trace
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--trace was set but no trace was recorded"))?;
        write_trace(trace, &path)?;
    }
    Ok(())
}

/// The numeric-mode (model, microbatch) set whose artifacts must exist for
/// examples and tests. `inspect --emit-registry` writes the union of their
/// primitive instances.
fn numeric_set() -> Vec<(hyparflow::graph::ModelGraph, usize)> {
    vec![
        // Tiny shapes for unit tests.
        (zoo::mlp(4, &[4], 3), 2),
        // Equivalence/integration tests.
        (zoo::mlp(8, &[8, 8, 8], 4), 4),
        (zoo::resnet20_v1(), 4),
        // Fused conv-bn-relu variant (perf-pass ablation).
        (hyparflow::graph::fuse::fuse_conv_bn_relu(&zoo::resnet20_v1()).0, 4),
        // Examples (quickstart, fig14/15/16 scaled accuracy runs).
        (zoo::resnet20_v1(), 8),
        (zoo::resnet56_v1(), 8),
        (zoo::resnet_v2(29, &[3, 32, 32], 10), 8),
        (zoo::vgg16(&[3, 32, 32], 10), 8),
        // End-to-end ~100M-parameter driver.
        (zoo::wide_mlp_100m(), 16),
    ]
}

fn cmd_inspect(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    if f.has("emit-registry") {
        let mut lines: BTreeSet<String> = BTreeSet::new();
        // Keep the hand-listed tiny shapes used by runtime unit tests.
        for l in [
            "dense 2 4 3", "denserelu 2 4 3", "relu2 2 4", "softmaxxent 2 3",
            "conv3x3 2 3 4 8 8 1", "bn 2 4 8 8", "relu4 2 4 8 8", "gap 2 4 8 8",
            "maxpool2 2 4 8 8", "conv1x1 2 4 8 8 8 2",
        ] {
            lines.insert(l.to_string());
        }
        for (g, mb) in numeric_set() {
            for l in artifact::registry_lines(&g, mb) {
                lines.insert(l);
            }
        }
        let header = "\
# Primitive-instance registry (GENERATED by `hyparflow inspect --emit-registry`).
# One instance per line: `prim p1 p2 ...` — see model.PARAM_ORDER for the
# per-primitive parameter order. `make artifacts` compiles each instance's
# fwd/bwd to artifacts/*.hlo.txt.
";
        let body: Vec<String> = lines.into_iter().collect();
        let out = format!("{header}{}\n", body.join("\n"));
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/python/compile/registry.txt");
        std::fs::write(path, &out)?;
        println!("wrote {} instances to {path}", body.len());
        return Ok(());
    }
    let g = zoo::by_name(&f.str("model", "resnet20"))?;
    println!(
        "{}: {} nodes, {} weight layers, {} params, {:.2} GFLOP/sample fwd",
        g.name,
        g.num_nodes(),
        g.num_weight_layers(),
        hyparflow::util::fmt_si(g.num_params() as f64),
        g.total_flops() / 1e9
    );
    let p: usize = f.get("partitions", 0)?;
    if p > 0 {
        let pt = Partitioning::auto(&g, p)?;
        println!(
            "partitioned into {p}: {} cross edges, {} boundary bytes/sample",
            pt.edges.len(),
            pt.boundary_bytes_per_sample(&g)
        );
        for i in 0..p {
            let flops: f64 = pt.parts[i].iter().map(|&n| g.node_cost(n).flops).sum();
            println!(
                "  partition {i}: {} nodes, {} params, {:.2} MFLOP/sample",
                pt.parts[i].len(),
                pt.params_of(&g, i),
                flops / 1e6
            );
        }
    }
    Ok(())
}

fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    use hyparflow::sim::{simulate, simulate_traced, Platform, SimConfig};
    let f = Flags::parse(args)?;
    let g = zoo::by_name(&f.str("model", "resnet110"))?;
    let platform = Platform::by_name(&f.str("platform", "skylake"))?;
    let partitions: usize = f.get("partitions", 16)?;
    let replicas: usize = f.get("replicas", 1)?;
    let nodes: usize = f.get("nodes", 1)?;
    let mut cfg = SimConfig::new(platform, partitions, replicas);
    cfg.nodes = nodes;
    cfg.ppn = f.get("ppn", (partitions * replicas).div_ceil(nodes))?;
    cfg.microbatch = f.get("mb", 4)?;
    cfg.num_microbatches = f.get("num-mb", 8)?;
    cfg.schedule = sched_flag(&f)?;
    // Stage-level partitioning: `partitions` ranks, `partitions * v`
    // chunks under interleaved schedules.
    let pt = cfg.schedule.partitioning(&g, partitions)?;
    cfg.overlap_allreduce = !f.has("no-overlap");
    if f.has("calibrate") {
        // Measure this host's kernels, persist the cost table, and feed it
        // straight into the simulation (satellite of the kernel-perf PR:
        // simulator constants track the real executor). `--calib-out
        // x.json` writes the full post-calibration cost table as JSON
        // (round-trips through `--calib`); any other name gets the raw
        // measured `key value` text.
        let text = hyparflow::figures::measure_calibration()?;
        cfg.cost.apply_calibration(&text)?;
        let out = f.str("calib-out", "calibration.txt");
        if out.ends_with(".json") {
            std::fs::write(&out, cfg.cost.to_json())?;
        } else {
            std::fs::write(&out, &text)?;
        }
        print!("{text}");
        println!("wrote {out}");
    } else if let Some(path) = f.kv.get("calib") {
        let text = std::fs::read_to_string(path)?;
        cfg.cost.apply_calibration(&text)?;
    }
    let trace_out = trace_flag(&f)?;
    let r = if let Some(path) = &trace_out {
        let (r, trace) = simulate_traced(&g, &pt, &cfg);
        write_trace(&trace, path)?;
        r
    } else {
        simulate(&g, &pt, &cfg)
    };
    println!(
        "sim {} on {} | nodes={nodes} ppn={} P={partitions} R={replicas} \
         mb={}x{} (EBS {}) sched={}",
        g.name, cfg.platform.name, cfg.ppn, cfg.microbatch, cfg.num_microbatches,
        cfg.effective_batch(), cfg.schedule.label()
    );
    println!(
        "  {:.1} img/s | step {:.4}s | compute {:.4}s bubble {:.4}s \
         p2p {:.4}s allreduce {:.4}s | peak mem {}",
        r.img_per_sec,
        r.step_secs,
        r.breakdown.compute_secs,
        r.breakdown.bubble_secs,
        r.breakdown.p2p_secs,
        r.breakdown.allreduce_secs,
        hyparflow::util::fmt_bytes(r.breakdown.mem_bytes)
    );
    Ok(())
}

fn cmd_calibrate(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let out = f.str("out", "calibration.txt");
    let text = hyparflow::figures::measure_calibration()?;
    std::fs::write(&out, &text)?;
    println!("{text}wrote {out}");
    Ok(())
}

fn cmd_mem(args: &[String]) -> anyhow::Result<()> {
    use hyparflow::mem;
    use hyparflow::schedule::Program;
    let f = Flags::parse(args)?;
    anyhow::ensure!(
        !f.kv.contains_key("image-size"),
        "--image-size is not supported here: model resolution is part of the \
         zoo variant (all CLI models are 32x32); the paper's image-size sweep \
         is `figures::fig01_memory` / `cargo bench --bench fig01_memory`"
    );
    let g = zoo::by_name(&f.str("model", "resnet1001"))?;
    let mb: usize = f.get("mb", 1)?;
    let parts: usize = f.get("partitions", 1)?;
    let num_mb: usize = f.get("num-mb", 0)?;
    if num_mb > 0 {
        // Schedule-aware report: peak residency from the program's stash
        // live intervals — the memory-model view of the shared IR.
        // Default matches train/sim so unflagged cross-command comparisons
        // describe the same schedule.
        let sched = sched_flag(&f)?;
        let pt = sched.partitioning(&g, parts.max(1))?;
        let prog = Program::compile(&g, &pt, num_mb, sched);
        let e = mem::scheduled_memory(&g, &pt, mb, &prog);
        println!(
            "{} mb={mb}x{num_mb} partitions={} sched={}: peak {:.2} GB \
             (worst-rank resident microbatches: {})",
            g.name,
            prog.num_partitions,
            sched.label(),
            e.total_gb(),
            prog.max_peak_resident_microbatches(),
        );
        for (name, budget) in [
            ("P100-16GB", mem::budgets::PASCAL_GB),
            ("V100-32GB", mem::budgets::VOLTA_GB),
            ("Skylake-192GB", mem::budgets::SKYLAKE_GB),
        ] {
            println!(
                "  {name}: {}",
                if mem::trainable(&e, budget) { "trainable" } else { "NOT trainable" }
            );
        }
        return Ok(());
    }
    let e = if parts <= 1 {
        mem::sequential_memory(&g, mb)
    } else {
        mem::mp_memory(&g, parts, mb)?
    };
    println!(
        "{} mb={mb} partitions={parts}: total {:.2} GB \
         (weights {} grads {} opt {} acts {} workspace {} framework {})",
        g.name,
        e.total_gb(),
        hyparflow::util::fmt_bytes(e.weights),
        hyparflow::util::fmt_bytes(e.gradients),
        hyparflow::util::fmt_bytes(e.optimizer),
        hyparflow::util::fmt_bytes(e.activations),
        hyparflow::util::fmt_bytes(e.workspace),
        hyparflow::util::fmt_bytes(e.framework),
    );
    for (name, budget) in [
        ("P100-16GB", mem::budgets::PASCAL_GB),
        ("V100-32GB", mem::budgets::VOLTA_GB),
        ("Skylake-192GB", mem::budgets::SKYLAKE_GB),
    ] {
        println!("  {name}: {}", if mem::trainable(&e, budget) { "trainable" } else { "NOT trainable" });
    }
    Ok(())
}
