//! Small shared helpers: human-readable units, timing, a tiny
//! line-oriented table printer, and a minimal JSON emitter used by the
//! bench harnesses (offline build: no serde).

use std::time::Instant;

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a count with SI units (e.g. parameter counts, FLOPs).
pub fn fmt_si(n: f64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Time a closure, returning (result, elapsed seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Parse a boolean environment flag strictly: `1|true|on` / `0|false|off`,
/// absent means `default`. Anything else is a hard error naming the
/// variable and the accepted spellings — mirroring `ScheduleKind::parse`, a
/// typo'd flag must not silently select a default behavior.
pub fn env_flag(name: &str, default: bool) -> anyhow::Result<bool> {
    match std::env::var(name) {
        Err(std::env::VarError::NotPresent) => Ok(default),
        Err(std::env::VarError::NotUnicode(v)) => {
            anyhow::bail!("{name}={v:?} is not unicode (valid values: 1|true|on|0|false|off)")
        }
        Ok(v) => match v.as_str() {
            "1" | "true" | "on" => Ok(true),
            "0" | "false" | "off" => Ok(false),
            other => anyhow::bail!(
                "{name}={other:?}: unrecognized flag value (valid values: 1|true|on|0|false|off)"
            ),
        },
    }
}

/// Parse an environment variable through `FromStr`, strictly: absent means
/// `default`, present-but-unparseable is a hard error naming the variable
/// and value — same policy as [`env_flag`], a typo'd setting must not
/// silently select a default.
pub fn env_parse<T: std::str::FromStr>(name: &str, default: T) -> anyhow::Result<T>
where
    T::Err: std::fmt::Display,
{
    match std::env::var(name) {
        Err(std::env::VarError::NotPresent) => Ok(default),
        Err(std::env::VarError::NotUnicode(v)) => anyhow::bail!("{name}={v:?} is not unicode"),
        Ok(v) => parse_env_value(name, &v),
    }
}

/// The value-level half of [`env_parse`], split out so strictness is unit
/// testable for variables (like `HFMPI_TIMEOUT_SECS`) that concurrently
/// running tests in the same binary read from the real, process-global
/// environment.
pub fn parse_env_value<T: std::str::FromStr>(name: &str, value: &str) -> anyhow::Result<T>
where
    T::Err: std::fmt::Display,
{
    value.parse().map_err(|e| anyhow::anyhow!("{name}={value:?}: {e}"))
}

/// Minimal fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$} | ", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&"-".repeat(wi + 2));
            sep.push('|');
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

// ---------------------------------------------------------------------------
// Minimal JSON emission (BENCH_*.json artifacts; offline build — no serde)
// ---------------------------------------------------------------------------

/// Escape a string for inclusion inside JSON double quotes.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A finite f64 as a JSON number (shortest round-trip form); non-finite
/// values become `null` (JSON has no NaN/Inf).
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// A JSON array from already-serialized element strings.
pub fn json_array(items: impl IntoIterator<Item = String>) -> String {
    let body: Vec<String> = items.into_iter().collect();
    format!("[{}]", body.join(","))
}

/// Incremental JSON object builder (fields keep insertion order).
pub struct JsonObj {
    fields: Vec<String>,
}

impl JsonObj {
    pub fn new() -> Self {
        JsonObj { fields: vec![] }
    }

    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.fields.push(format!("\"{}\":\"{}\"", json_escape(k), json_escape(v)));
        self
    }

    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.fields.push(format!("\"{}\":{}", json_escape(k), json_num(v)));
        self
    }

    pub fn int(mut self, k: &str, v: u64) -> Self {
        self.fields.push(format!("\"{}\":{v}", json_escape(k)));
        self
    }

    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.fields.push(format!("\"{}\":{v}", json_escape(k)));
        self
    }

    /// Attach an already-serialized JSON value (array / nested object).
    pub fn raw(mut self, k: &str, v: &str) -> Self {
        self.fields.push(format!("\"{}\":{v}", json_escape(k)));
        self
    }

    pub fn build(self) -> String {
        format!("{{{}}}", self.fields.join(","))
    }
}

impl Default for JsonObj {
    fn default() -> Self {
        JsonObj::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(16_800_000_000), "15.65 GiB");
    }

    #[test]
    fn si_units() {
        assert_eq!(fmt_si(30_000_000.0), "30.00M");
        assert_eq!(fmt_si(999.0), "999.00");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_secs(2.0), "2.000s");
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_object_and_array() {
        let arr = json_array(["1".to_string(), "2".to_string()]);
        let o = JsonObj::new()
            .str("name", "x")
            .num("v", 1.5)
            .int("n", 3)
            .bool("ok", true)
            .raw("xs", &arr)
            .build();
        assert_eq!(o, "{\"name\":\"x\",\"v\":1.5,\"n\":3,\"ok\":true,\"xs\":[1,2]}");
    }

    #[test]
    fn json_non_finite_is_null() {
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
        assert_eq!(json_num(0.25), "0.25");
    }

    #[test]
    fn env_flag_is_strict() {
        // Distinct variable names per assertion: tests in this binary run
        // concurrently and the environment is process-global.
        assert!(env_flag("HF_TEST_FLAG_UNSET", true).unwrap());
        assert!(!env_flag("HF_TEST_FLAG_UNSET", false).unwrap());
        std::env::set_var("HF_TEST_FLAG_ON", "on");
        assert!(env_flag("HF_TEST_FLAG_ON", false).unwrap());
        std::env::set_var("HF_TEST_FLAG_OFF", "0");
        assert!(!env_flag("HF_TEST_FLAG_OFF", true).unwrap());
        std::env::set_var("HF_TEST_FLAG_BAD", "banana");
        let err = env_flag("HF_TEST_FLAG_BAD", true).unwrap_err().to_string();
        assert!(err.contains("HF_TEST_FLAG_BAD") && err.contains("banana"), "{err}");
        assert!(err.contains("1|true|on|0|false|off"), "{err}");
    }

    #[test]
    fn env_parse_is_strict() {
        // Distinct variable names per assertion (see env_flag_is_strict).
        assert_eq!(env_parse("HF_TEST_PARSE_UNSET", 120u64).unwrap(), 120);
        std::env::set_var("HF_TEST_PARSE_SET", "45");
        assert_eq!(env_parse("HF_TEST_PARSE_SET", 120u64).unwrap(), 45);
        std::env::set_var("HF_TEST_PARSE_BAD", "soon");
        let err = env_parse("HF_TEST_PARSE_BAD", 120u64).unwrap_err().to_string();
        assert!(err.contains("HF_TEST_PARSE_BAD") && err.contains("soon"), "{err}");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("| a | bb |") || s.contains("a"));
        assert_eq!(s.lines().count(), 3);
    }
}
