//! Small shared helpers: human-readable units, timing, and a tiny
//! line-oriented table printer used by the bench harnesses.

use std::time::Instant;

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a count with SI units (e.g. parameter counts, FLOPs).
pub fn fmt_si(n: f64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Time a closure, returning (result, elapsed seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Minimal fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$} | ", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        let mut sep = String::from("|");
        for wi in &w {
            sep.push_str(&"-".repeat(wi + 2));
            sep.push('|');
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(16_800_000_000), "15.65 GiB");
    }

    #[test]
    fn si_units() {
        assert_eq!(fmt_si(30_000_000.0), "30.00M");
        assert_eq!(fmt_si(999.0), "999.00");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_secs(2.0), "2.000s");
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("| a | bb |") || s.contains("a"));
        assert_eq!(s.lines().count(), 3);
    }
}
