//! Structural validator for exported Chrome trace-event JSON.
//!
//! Checks what the conformance tests and CI rely on: the document is
//! well-formed JSON with a `traceEvents` array, per-rank (pid) timestamps
//! are monotonically nondecreasing, `B`/`E` duration spans are properly
//! nested (LIFO with matching names), and async `b`/`e` send-window pairs
//! close exactly once. The offline build has no serde, so this carries its
//! own minimal recursive-descent JSON parser.

use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// Minimal JSON value (parse-side twin of the emitter in `util.rs`).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

/// Parse a JSON document (full standard grammar; enough for our exports).
pub fn parse_json(s: &str) -> Result<Json> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    ensure!(p.pos == p.b.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.pos))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(self.peek()? == c, "expected {:?} at byte {}", c as char, self.pos);
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.pos..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += s.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.pos + 4 <= self.b.len(), "truncated \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogates are not emitted by our exporter;
                            // map unpaired ones to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{} at byte {}", e as char, self.pos),
                    }
                }
                _ => {
                    // Re-scan from the byte we consumed so multi-byte UTF-8
                    // sequences stay intact.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.b[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            fields.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, c as char),
            }
        }
    }
}

/// Counts from a successful validation run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    /// Distinct pids (ranks) seen.
    pub ranks: usize,
    /// Total trace events (including metadata).
    pub events: usize,
    /// Completed `B`/`E` duration spans.
    pub spans: usize,
    /// Completed async `b`/`e` send windows.
    pub windows: usize,
}

/// Validate a Chrome trace-event JSON document structurally.
pub fn validate_chrome_trace(doc: &str) -> Result<TraceCheck> {
    let root = parse_json(doc)?;
    let Some(Json::Arr(events)) = root.get("traceEvents") else {
        bail!("document has no traceEvents array");
    };
    let mut check = TraceCheck { events: events.len(), ..Default::default() };
    let mut last_ts: HashMap<u64, f64> = HashMap::new();
    let mut stacks: HashMap<(u64, u64), Vec<String>> = HashMap::new();
    // (pid, cat, id) -> Some(open b ts) / None once closed.
    let mut windows: HashMap<(u64, String, String), Option<f64>> = HashMap::new();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no ph"))?
            .to_string();
        let pid = ev
            .get("pid")
            .and_then(Json::as_num)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no pid"))? as u64;
        if !last_ts.contains_key(&pid) {
            check.ranks += 1;
            last_ts.insert(pid, f64::NEG_INFINITY);
        }
        if ph == "M" {
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(Json::as_num)
            .ok_or_else(|| anyhow::anyhow!("event {i} (ph {ph}) has no ts"))?;
        let prev = last_ts[&pid];
        ensure!(
            ts >= prev,
            "pid {pid}: ts went backwards at event {i} ({ts} < {prev})"
        );
        last_ts.insert(pid, ts);
        let name = ev.get("name").and_then(Json::as_str).unwrap_or_default().to_string();
        let tid = ev.get("tid").and_then(Json::as_num).unwrap_or(0.0) as u64;
        match ph.as_str() {
            "B" => stacks.entry((pid, tid)).or_default().push(name),
            "E" => {
                let top = stacks.entry((pid, tid)).or_default().pop();
                match top {
                    Some(open) => ensure!(
                        open == name,
                        "pid {pid}: E {:?} at event {i} closes open span {:?}",
                        name,
                        open
                    ),
                    None => bail!("pid {pid}: E {:?} at event {i} with empty span stack", name),
                }
                check.spans += 1;
            }
            "b" | "e" => {
                let cat = ev.get("cat").and_then(Json::as_str).unwrap_or_default().to_string();
                let id = match ev.get("id") {
                    Some(Json::Num(n)) => format!("{n}"),
                    Some(Json::Str(s)) => s.clone(),
                    _ => bail!("async event {i} has no id"),
                };
                let key = (pid, cat, id);
                if ph == "b" {
                    match windows.entry(key) {
                        std::collections::hash_map::Entry::Occupied(e) => {
                            bail!("async window {:?} opened twice (event {i})", e.key())
                        }
                        std::collections::hash_map::Entry::Vacant(v) => {
                            v.insert(Some(ts));
                        }
                    }
                } else {
                    match windows.get_mut(&key) {
                        Some(slot) => match slot.take() {
                            Some(t_open) => {
                                ensure!(
                                    ts >= t_open,
                                    "async window {key:?} closes before it opens"
                                );
                                check.windows += 1;
                            }
                            None => bail!("async window {key:?} closed twice (event {i})"),
                        },
                        None => bail!("async window {key:?} closed without opening (event {i})"),
                    }
                }
            }
            other => bail!("event {i}: unsupported ph {other:?}"),
        }
    }
    for ((pid, tid), stack) in &stacks {
        ensure!(
            stack.is_empty(),
            "pid {pid} tid {tid}: {} span(s) left open: {:?}",
            stack.len(),
            stack
        );
    }
    for (key, open) in &windows {
        ensure!(open.is_none(), "async window {key:?} never closed");
    }
    Ok(check)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(events: &str) -> String {
        format!("{{\"traceEvents\":[{events}]}}")
    }

    #[test]
    fn accepts_a_well_formed_trace() {
        let d = doc(
            r#"{"name":"process_name","ph":"M","pid":0,"tid":0,"args":{"name":"rank 0"}},
               {"name":"fwd","cat":"compute","ph":"B","pid":0,"tid":0,"ts":0.5,"args":{"seq":0}},
               {"name":"exec","cat":"runtime","ph":"B","pid":0,"tid":0,"ts":1.0},
               {"name":"exec","ph":"E","pid":0,"tid":0,"ts":2.0},
               {"name":"fwd","ph":"E","pid":0,"tid":0,"ts":2.5},
               {"name":"send-window","cat":"send-window","ph":"b","id":0,"pid":0,"tid":0,"ts":3.0},
               {"name":"send-window","cat":"send-window","ph":"e","id":0,"pid":0,"tid":0,"ts":4.0}"#,
        );
        let c = validate_chrome_trace(&d).unwrap();
        assert_eq!(c, TraceCheck { ranks: 1, events: 7, spans: 2, windows: 1 });
    }

    #[test]
    fn rejects_nonmonotonic_timestamps() {
        let d = doc(
            r#"{"name":"a","ph":"B","pid":0,"tid":0,"ts":5.0},
               {"name":"a","ph":"E","pid":0,"tid":0,"ts":4.0}"#,
        );
        let e = validate_chrome_trace(&d).unwrap_err().to_string();
        assert!(e.contains("ts went backwards"), "{e}");
    }

    #[test]
    fn rejects_mismatched_span_nesting() {
        let d = doc(
            r#"{"name":"a","ph":"B","pid":0,"tid":0,"ts":0},
               {"name":"b","ph":"B","pid":0,"tid":0,"ts":1},
               {"name":"a","ph":"E","pid":0,"tid":0,"ts":2}"#,
        );
        assert!(validate_chrome_trace(&d).is_err());
        let d = doc(r#"{"name":"a","ph":"E","pid":0,"tid":0,"ts":0}"#);
        assert!(validate_chrome_trace(&d).is_err());
    }

    #[test]
    fn rejects_unbalanced_async_windows() {
        let open_only =
            doc(r#"{"name":"w","cat":"sw","ph":"b","id":1,"pid":0,"tid":0,"ts":0}"#);
        assert!(validate_chrome_trace(&open_only).is_err());
        let double_close = doc(
            r#"{"name":"w","cat":"sw","ph":"b","id":1,"pid":0,"tid":0,"ts":0},
               {"name":"w","cat":"sw","ph":"e","id":1,"pid":0,"tid":0,"ts":1},
               {"name":"w","cat":"sw","ph":"e","id":1,"pid":0,"tid":0,"ts":2}"#,
        );
        assert!(validate_chrome_trace(&double_close).is_err());
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(validate_chrome_trace("{\"traceEvents\":[").is_err());
        assert!(validate_chrome_trace("[]").is_err());
        assert!(parse_json("{\"a\":1,}").is_err());
        // Escapes and nesting round-trip through the mini parser.
        let v = parse_json(r#"{"s":"a\"bA","arr":[1,-2.5e3,true,null]}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("a\"bA"));
    }
}
