//! hftrace — per-rank structured runtime tracing.
//!
//! A trace is a per-rank, append-only buffer of typed spans keyed to the
//! schedule IR: every interpreted [`Instr`](crate::schedule::Instr) becomes an
//! [`Event`] tagged with rank, microbatch, stage and bytes, carrying monotonic
//! wall-clock timestamps *plus* a timing-independent logical sequence number
//! (the push index). Finer spans nest inside the IR spans: the communication
//! engine records `comm.*` sub-spans (send/recv/wait/allreduce/bcast) and the
//! runtime records `exec` kernel spans, all through the same [`Tracer`] handle.
//!
//! The simulator emits the **same schema** from its DES clock
//! ([`crate::sim::simulate_program_traced`]), which is what makes simulated
//! and measured timelines directly comparable — both sides build events with
//! [`instr_event`], so kinds, tags and byte counts match field-for-field and
//! only the clocks differ.
//!
//! Consumers:
//! - [`chrome`] — merged multi-rank Chrome trace-event JSON (pid = world
//!   rank), loadable in Perfetto / `chrome://tracing`. Post→wait send windows
//!   become async spans.
//! - [`report`] — aggregate per-kind totals, measured bubble fraction, and
//!   the overlap ratio (post→wait window time overlapped with compute).
//! - [`validate`] — structural checker for exported Chrome JSON (used by the
//!   conformance tests and CI).
//!
//! Tracing is strictly observation-only and zero-cost when disabled: a
//! disabled [`Tracer`] never reads the clock and never allocates
//! ([`Tracer::start`] returns `None` and [`Tracer::record`] drops the closure
//! unevaluated), and no payload, ordering or arithmetic depends on it.

pub mod chrome;
pub mod report;
pub mod validate;

use crate::graph::ModelGraph;
use crate::partition::Partitioning;
use crate::schedule::Instr;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::OnceLock;
use std::time::Instant;

/// What a span measures. IR kinds mirror [`Instr`]; `Comm*` and `Exec` are
/// finer-grained spans nested inside them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    FwdCompute,
    BwdCompute,
    BwdInput,
    BwdWeight,
    SendActivation,
    RecvActivation,
    SendError,
    RecvError,
    PostSendActivation,
    PostSendError,
    WaitSend,
    DropStash,
    AllreduceGrads,
    OptStep,
    /// Blocking transport send inside a send/post-send IR span.
    CommSend,
    /// Blocking transport recv inside a recv IR span.
    CommRecv,
    /// Completion of a posted send inside a `WaitSend` IR span.
    CommWait,
    /// Fused allreduce (gradients or metrics). Only emitted with >1 replica.
    CommAllreduce,
    /// Parameter broadcast. Only emitted with >1 replica.
    CommBcast,
    /// One native kernel execution (artifact name in `label`).
    Exec,
}

impl EventKind {
    /// Stable lowercase name used in exports and golden listings.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::FwdCompute => "fwd",
            EventKind::BwdCompute => "bwd",
            EventKind::BwdInput => "bwd_input",
            EventKind::BwdWeight => "bwd_weight",
            EventKind::SendActivation => "send_act",
            EventKind::RecvActivation => "recv_act",
            EventKind::SendError => "send_err",
            EventKind::RecvError => "recv_err",
            EventKind::PostSendActivation => "post_send_act",
            EventKind::PostSendError => "post_send_err",
            EventKind::WaitSend => "wait_send",
            EventKind::DropStash => "drop_stash",
            EventKind::AllreduceGrads => "allreduce_grads",
            EventKind::OptStep => "opt_step",
            EventKind::CommSend => "comm.send",
            EventKind::CommRecv => "comm.recv",
            EventKind::CommWait => "comm.wait",
            EventKind::CommAllreduce => "comm.allreduce",
            EventKind::CommBcast => "comm.bcast",
            EventKind::Exec => "exec",
        }
    }

    /// IR compute spans. `Exec` spans nest *inside* these, so they are
    /// excluded here to avoid double-counting compute time.
    pub fn is_compute(self) -> bool {
        matches!(
            self,
            EventKind::FwdCompute
                | EventKind::BwdCompute
                | EventKind::BwdInput
                | EventKind::BwdWeight
        )
    }

    /// Chrome trace category.
    pub fn category(self) -> &'static str {
        match self {
            k if k.is_compute() => "compute",
            EventKind::CommSend
            | EventKind::CommRecv
            | EventKind::CommWait
            | EventKind::CommAllreduce
            | EventKind::CommBcast => "comm",
            EventKind::Exec => "runtime",
            _ => "schedule",
        }
    }
}

/// One closed span on one rank's timeline. `t0`/`t1` are seconds since the
/// process-global trace epoch; `seq` is the logical (timing-independent)
/// position in the rank's buffer.
#[derive(Clone, Debug)]
pub struct Event {
    pub kind: EventKind,
    pub seq: u64,
    pub t0: f64,
    pub t1: f64,
    pub node: Option<usize>,
    pub stage: Option<usize>,
    pub mb: Option<usize>,
    pub edge: Option<usize>,
    pub peer: Option<usize>,
    pub handle: Option<usize>,
    pub bytes: Option<u64>,
    pub label: Option<String>,
}

impl Event {
    /// A bare span of `kind`; tags are attached with the builder methods and
    /// timestamps are filled in by [`Tracer::record`] (or the simulator).
    pub fn span(kind: EventKind) -> Event {
        Event {
            kind,
            seq: 0,
            t0: 0.0,
            t1: 0.0,
            node: None,
            stage: None,
            mb: None,
            edge: None,
            peer: None,
            handle: None,
            bytes: None,
            label: None,
        }
    }

    pub fn node(mut self, n: usize) -> Self {
        self.node = Some(n);
        self
    }
    pub fn stage(mut self, s: usize) -> Self {
        self.stage = Some(s);
        self
    }
    pub fn mb(mut self, m: usize) -> Self {
        self.mb = Some(m);
        self
    }
    pub fn edge(mut self, e: usize) -> Self {
        self.edge = Some(e);
        self
    }
    pub fn peer(mut self, p: usize) -> Self {
        self.peer = Some(p);
        self
    }
    pub fn handle(mut self, h: usize) -> Self {
        self.handle = Some(h);
        self
    }
    pub fn bytes(mut self, b: u64) -> Self {
        self.bytes = Some(b);
        self
    }
    pub fn label(mut self, l: &str) -> Self {
        self.label = Some(l.to_string());
        self
    }

    /// Timestamp-free rendering for golden listings: kind plus tags in a
    /// fixed order, mirroring the schedule IR's program notation.
    pub fn logical_label(&self) -> String {
        let mut s = self.kind.name().to_string();
        if let Some(l) = &self.label {
            s.push_str(&format!(" [{l}]"));
        }
        if let Some(n) = self.node {
            s.push_str(&format!(" n{n}"));
        }
        if let Some(st) = self.stage {
            s.push_str(&format!(" s{st}"));
        }
        if let Some(e) = self.edge {
            s.push_str(&format!(" e{e}"));
        }
        if let Some(p) = self.peer {
            s.push_str(&format!(" r{p}"));
        }
        if let Some(m) = self.mb {
            s.push_str(&format!(" mb{m}"));
        }
        if let Some(h) = self.handle {
            s.push_str(&format!(" h{h}"));
        }
        if let Some(b) = self.bytes {
            s.push_str(&format!(" {b}B"));
        }
        s
    }
}

/// One rank's append-only event buffer.
#[derive(Clone, Debug, Default)]
pub struct RankTrace {
    pub rank: usize,
    pub events: Vec<Event>,
}

impl RankTrace {
    pub fn new(rank: usize) -> RankTrace {
        RankTrace { rank, events: Vec::new() }
    }

    /// Append `ev`, assigning the next logical sequence number.
    pub fn push(&mut self, mut ev: Event) {
        ev.seq = self.events.len() as u64;
        self.events.push(ev);
    }
}

/// A merged multi-rank trace (ranks in world-rank order).
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub ranks: Vec<RankTrace>,
}

impl Trace {
    pub fn num_events(&self) -> usize {
        self.ranks.iter().map(|r| r.events.len()).sum()
    }

    /// Timestamp-free listing of every rank's logical event sequence — the
    /// deterministic view blessed by the golden trace test.
    pub fn logical_listing(&self) -> String {
        let mut out = String::new();
        for r in &self.ranks {
            out.push_str(&format!("rank {}\n", r.rank));
            for ev in &r.events {
                out.push_str("  ");
                out.push_str(&ev.logical_label());
                out.push('\n');
            }
        }
        out
    }

    /// Split a multi-step trace into per-step traces at `OptStep`
    /// boundaries (each slice ends with its rank's `OptStep` event).
    pub fn split_steps(&self) -> Vec<Trace> {
        let steps = self
            .ranks
            .iter()
            .map(|r| r.events.iter().filter(|e| e.kind == EventKind::OptStep).count())
            .min()
            .unwrap_or(0);
        let mut out: Vec<Trace> = (0..steps)
            .map(|_| Trace {
                ranks: self.ranks.iter().map(|r| RankTrace::new(r.rank)).collect(),
            })
            .collect();
        for (ri, r) in self.ranks.iter().enumerate() {
            let mut k = 0;
            for ev in &r.events {
                if k < steps {
                    out[k].ranks[ri].events.push(ev.clone());
                }
                if ev.kind == EventKind::OptStep {
                    k += 1;
                }
            }
        }
        out
    }
}

/// All rank threads live in one process, so one monotonic epoch serves every
/// rank; timestamps from different ranks are directly comparable.
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn now() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Cheap cloneable recording handle. Disabled (`Tracer::off`) it is a `None`
/// and costs nothing: no clock reads, no allocation, the event-building
/// closure passed to [`Tracer::record`] is never evaluated.
///
/// Deliberately `!Send` (per-rank, like the `Runtime`); the finished
/// [`RankTrace`] extracted by [`Tracer::take`] is plain data and crosses
/// thread boundaries freely.
#[derive(Clone, Default)]
pub struct Tracer(Option<Rc<RefCell<RankTrace>>>);

impl Tracer {
    /// A disabled tracer.
    pub fn off() -> Tracer {
        Tracer(None)
    }

    /// An enabled tracer recording into a fresh buffer for `rank`.
    pub fn on(rank: usize) -> Tracer {
        EPOCH.get_or_init(Instant::now);
        Tracer(Some(Rc::new(RefCell::new(RankTrace::new(rank)))))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Open a span: returns the start timestamp, or `None` (without touching
    /// the clock) when disabled.
    #[inline]
    pub fn start(&self) -> Option<f64> {
        self.0.as_ref().map(|_| now())
    }

    /// Close a span opened by [`start`](Tracer::start). `build` supplies the
    /// kind/tags; it is only evaluated when tracing is enabled.
    #[inline]
    pub fn record(&self, t0: Option<f64>, build: impl FnOnce() -> Event) {
        if let (Some(buf), Some(t0)) = (self.0.as_ref(), t0) {
            let t1 = now();
            let mut ev = build();
            ev.t0 = t0;
            ev.t1 = t1;
            buf.borrow_mut().push(ev);
        }
    }

    /// Extract the recorded buffer, leaving an empty one behind (other
    /// clones of this tracer stay valid but start from empty).
    pub fn take(&self) -> Option<RankTrace> {
        self.0.as_ref().map(|buf| {
            let rank = buf.borrow().rank;
            std::mem::replace(&mut *buf.borrow_mut(), RankTrace::new(rank))
        })
    }
}

/// Payload bytes of one microbatch crossing `edge` (f32 activations).
/// Matches both the simulator's wire model and the engine's
/// `Tensor::size_bytes` for the same transfer.
pub fn edge_bytes(g: &ModelGraph, pt: &Partitioning, edge: usize, microbatch: usize) -> u64 {
    let e = &pt.edges[edge];
    g.nodes[e.src_node].out_shape.iter().product::<usize>() as u64 * 4 * microbatch as u64
}

fn node_out_bytes(g: &ModelGraph, node: usize, microbatch: usize) -> u64 {
    g.nodes[node].out_shape.iter().product::<usize>() as u64 * 4 * microbatch as u64
}

/// Build the schema event for one schedule-IR instruction. Both the engine
/// (wall clock) and the simulator (DES clock) go through this constructor,
/// which is what keeps measured and simulated traces field-compatible.
/// `param_bytes` is the rank's resident parameter footprint (tagged onto
/// `AllreduceGrads`/`OptStep`).
pub fn instr_event(
    g: &ModelGraph,
    pt: &Partitioning,
    microbatch: usize,
    instr: &Instr,
    param_bytes: u64,
) -> Event {
    use EventKind as K;
    match *instr {
        Instr::FwdCompute { node, stage, mb } => Event::span(K::FwdCompute)
            .node(node)
            .stage(stage)
            .mb(mb)
            .bytes(node_out_bytes(g, node, microbatch)),
        Instr::BwdCompute { node, stage, mb } => Event::span(K::BwdCompute)
            .node(node)
            .stage(stage)
            .mb(mb)
            .bytes(node_out_bytes(g, node, microbatch)),
        Instr::BwdInput { node, stage, mb } => Event::span(K::BwdInput)
            .node(node)
            .stage(stage)
            .mb(mb)
            .bytes(node_out_bytes(g, node, microbatch)),
        Instr::BwdWeight { node, stage, mb } => Event::span(K::BwdWeight)
            .node(node)
            .stage(stage)
            .mb(mb)
            .bytes(node_out_bytes(g, node, microbatch)),
        Instr::SendActivation { edge, peer, mb } => Event::span(K::SendActivation)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::RecvActivation { edge, peer, mb } => Event::span(K::RecvActivation)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::SendError { edge, peer, mb } => Event::span(K::SendError)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::RecvError { edge, peer, mb } => Event::span(K::RecvError)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::PostSendActivation { edge, peer, mb, handle } => Event::span(K::PostSendActivation)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .handle(handle)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::PostSendError { edge, peer, mb, handle } => Event::span(K::PostSendError)
            .edge(edge)
            .peer(peer)
            .mb(mb)
            .handle(handle)
            .bytes(edge_bytes(g, pt, edge, microbatch)),
        Instr::WaitSend { handle } => Event::span(K::WaitSend).handle(handle),
        Instr::DropStash { mb } => Event::span(K::DropStash).mb(mb),
        Instr::AllreduceGrads => Event::span(K::AllreduceGrads).bytes(param_bytes),
        Instr::OptStep => Event::span(K::OptStep).bytes(param_bytes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing_and_never_builds() {
        let t = Tracer::off();
        assert!(!t.enabled());
        let tt = t.start();
        assert!(tt.is_none());
        t.record(tt, || unreachable!("closure must not run when disabled"));
        assert!(t.take().is_none());
    }

    #[test]
    fn enabled_tracer_assigns_monotonic_times_and_seqs() {
        let t = Tracer::on(3);
        for i in 0..4 {
            let tt = t.start();
            t.record(tt, || Event::span(EventKind::FwdCompute).node(i).mb(i));
        }
        let buf = t.take().unwrap();
        assert_eq!(buf.rank, 3);
        assert_eq!(buf.events.len(), 4);
        for (i, ev) in buf.events.iter().enumerate() {
            assert_eq!(ev.seq, i as u64);
            assert!(ev.t1 >= ev.t0);
            if i > 0 {
                assert!(ev.t0 >= buf.events[i - 1].t0);
            }
        }
        // take() left an empty buffer behind; the tracer keeps working.
        let tt = t.start();
        t.record(tt, || Event::span(EventKind::OptStep));
        assert_eq!(t.take().unwrap().events.len(), 1);
    }

    #[test]
    fn split_steps_cuts_at_opt_step() {
        let mut r0 = RankTrace::new(0);
        for _ in 0..2 {
            r0.push(Event::span(EventKind::FwdCompute).mb(0));
            r0.push(Event::span(EventKind::AllreduceGrads));
            r0.push(Event::span(EventKind::OptStep));
        }
        let tr = Trace { ranks: vec![r0] };
        let steps = tr.split_steps();
        assert_eq!(steps.len(), 2);
        for s in &steps {
            assert_eq!(s.ranks[0].events.len(), 3);
            assert_eq!(s.ranks[0].events.last().unwrap().kind, EventKind::OptStep);
        }
    }

    #[test]
    fn logical_label_is_timestamp_free_and_tagged() {
        let mut ev = Event::span(EventKind::PostSendActivation)
            .edge(2)
            .peer(1)
            .mb(3)
            .handle(7)
            .bytes(128);
        ev.t0 = 1.25;
        ev.t1 = 2.5;
        assert_eq!(ev.logical_label(), "post_send_act e2 r1 mb3 h7 128B");
    }
}
