//! Aggregate trace report: per-op-kind time totals, the measured bubble
//! fraction, and the post→wait overlap ratio.
//!
//! One implementation serves both measured (engine) and simulated (DES)
//! traces — they share the event schema — which is what the sim-vs-real
//! cross-validation test stands on.

use super::{EventKind, Trace};
use crate::util::{fmt_secs, json_array, JsonObj, Table};
use std::collections::{BTreeMap, HashMap};

/// Aggregates over one [`Trace`] (typically one training step — see
/// [`Trace::split_steps`]).
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    pub ranks: usize,
    pub events: usize,
    /// Wall span of the trace: latest `t1` minus earliest `t0`.
    pub step_secs: f64,
    /// Compute time of the busiest rank (IR compute spans only — nested
    /// kernel `exec` spans are not double-counted).
    pub compute_secs: f64,
    /// `(step - bottleneck compute) / step` — the same definition the
    /// simulator's `bubble_secs` implies, measured instead of modeled.
    pub bubble_frac: f64,
    /// Total duration of eager post→wait send windows.
    pub window_secs: f64,
    /// Window time overlapped with same-rank compute spans.
    pub overlap_secs: f64,
    /// `overlap_secs / window_secs` (0 when there are no windows).
    pub overlap_frac: f64,
    /// Per event kind: (total seconds, event count), sorted by kind name.
    pub per_kind: BTreeMap<&'static str, (f64, u64)>,
}

impl TraceReport {
    pub fn from_trace(trace: &Trace) -> TraceReport {
        let mut rep = TraceReport { ranks: trace.ranks.len(), ..Default::default() };
        let mut t_min = f64::INFINITY;
        let mut t_max = f64::NEG_INFINITY;
        let mut total_window = 0.0;
        let mut total_overlap = 0.0;
        for rank in &trace.ranks {
            rep.events += rank.events.len();
            let mut compute: Vec<(f64, f64)> = Vec::new();
            for ev in &rank.events {
                t_min = t_min.min(ev.t0);
                t_max = t_max.max(ev.t1);
                let slot = rep.per_kind.entry(ev.kind.name()).or_insert((0.0, 0));
                slot.0 += ev.t1 - ev.t0;
                slot.1 += 1;
                if ev.kind.is_compute() {
                    compute.push((ev.t0, ev.t1));
                }
            }
            let rank_compute: f64 = compute.iter().map(|(a, b)| b - a).sum();
            rep.compute_secs = rep.compute_secs.max(rank_compute);
            let merged = merge_intervals(compute);
            for (w0, w1) in send_windows(rank) {
                total_window += w1 - w0;
                total_overlap += intersect_secs(w0, w1, &merged);
            }
        }
        rep.step_secs = (t_max - t_min).max(0.0);
        rep.bubble_frac = if rep.step_secs > 0.0 {
            ((rep.step_secs - rep.compute_secs) / rep.step_secs).max(0.0)
        } else {
            0.0
        };
        rep.window_secs = total_window;
        rep.overlap_secs = total_overlap;
        rep.overlap_frac = if total_window > 0.0 { total_overlap / total_window } else { 0.0 };
        rep
    }

    /// Human-readable summary (bench-table style).
    pub fn render(&self) -> String {
        let mut out = format!(
            "trace: {} ranks, {} events, step {} | bottleneck compute {} (bubble frac {:.3}) | \
             send windows {} overlapped {} ({:.1}%)\n",
            self.ranks,
            self.events,
            fmt_secs(self.step_secs),
            fmt_secs(self.compute_secs),
            self.bubble_frac,
            fmt_secs(self.window_secs),
            fmt_secs(self.overlap_secs),
            self.overlap_frac * 100.0,
        );
        let mut t = Table::new(&["kind", "count", "total"]);
        for (kind, (secs, count)) in &self.per_kind {
            t.row(&[kind.to_string(), count.to_string(), fmt_secs(*secs)]);
        }
        out.push_str(&t.to_string());
        out
    }

    pub fn to_json(&self) -> String {
        let kinds = self.per_kind.iter().map(|(kind, (secs, count))| {
            JsonObj::new().str("kind", kind).int("count", *count).num("secs", *secs).build()
        });
        JsonObj::new()
            .int("ranks", self.ranks as u64)
            .int("events", self.events as u64)
            .num("step_secs", self.step_secs)
            .num("compute_secs", self.compute_secs)
            .num("bubble_frac", self.bubble_frac)
            .num("window_secs", self.window_secs)
            .num("overlap_secs", self.overlap_secs)
            .num("overlap_frac", self.overlap_frac)
            .raw("per_kind", &json_array(kinds))
            .build()
    }
}

/// Post→wait windows of one rank, paired by handle in logical order
/// (handles recycle across steps; within a step pairing is exactly-once).
fn send_windows(rank: &super::RankTrace) -> Vec<(f64, f64)> {
    let mut open: HashMap<usize, f64> = HashMap::new();
    let mut out = Vec::new();
    for ev in &rank.events {
        match ev.kind {
            EventKind::PostSendActivation | EventKind::PostSendError => {
                if let Some(h) = ev.handle {
                    open.insert(h, ev.t0);
                }
            }
            EventKind::WaitSend => {
                if let Some(t0) = ev.handle.and_then(|h| open.remove(&h)) {
                    out.push((t0, ev.t1));
                }
            }
            _ => {}
        }
    }
    out
}

/// Merge possibly-overlapping intervals into a disjoint sorted set.
fn merge_intervals(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut out: Vec<(f64, f64)> = Vec::with_capacity(iv.len());
    for (a, b) in iv {
        match out.last_mut() {
            Some(last) if a <= last.1 => last.1 = last.1.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// Seconds of `[w0, w1]` covered by the disjoint sorted intervals.
fn intersect_secs(w0: f64, w1: f64, merged: &[(f64, f64)]) -> f64 {
    merged
        .iter()
        .map(|&(a, b)| (b.min(w1) - a.max(w0)).max(0.0))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Event, RankTrace};

    fn ev(kind: EventKind, t0: f64, t1: f64) -> Event {
        let mut e = Event::span(kind);
        e.t0 = t0;
        e.t1 = t1;
        e
    }

    #[test]
    fn bubble_and_overlap_from_a_hand_built_trace() {
        // Rank 0: compute [0,4], window [3,6] -> 1s of 3 overlapped.
        let mut r0 = RankTrace::new(0);
        r0.push(ev(EventKind::PostSendActivation, 3.0, 3.0).handle(0));
        r0.push(ev(EventKind::FwdCompute, 0.0, 4.0));
        r0.push(ev(EventKind::WaitSend, 6.0, 6.0).handle(0));
        // Rank 1: compute [2,8] — the bottleneck (6s of a 10s step).
        let mut r1 = RankTrace::new(1);
        r1.push(ev(EventKind::BwdCompute, 2.0, 8.0));
        r1.push(ev(EventKind::OptStep, 8.0, 10.0));
        let rep = TraceReport::from_trace(&Trace { ranks: vec![r0, r1] });
        assert_eq!(rep.step_secs, 10.0);
        assert_eq!(rep.compute_secs, 6.0);
        assert!((rep.bubble_frac - 0.4).abs() < 1e-12);
        assert_eq!(rep.window_secs, 3.0);
        assert_eq!(rep.overlap_secs, 1.0);
        assert!((rep.overlap_frac - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(rep.per_kind["fwd"], (4.0, 1));
        // Serialization paths stay well-formed.
        assert!(rep.render().contains("bubble frac"));
        assert!(rep.to_json().contains("\"overlap_frac\""));
    }

    #[test]
    fn no_windows_means_zero_overlap_not_nan() {
        let mut r = RankTrace::new(0);
        r.push(ev(EventKind::FwdCompute, 0.0, 1.0));
        let rep = TraceReport::from_trace(&Trace { ranks: vec![r] });
        assert_eq!(rep.window_secs, 0.0);
        assert_eq!(rep.overlap_frac, 0.0);
    }
}
