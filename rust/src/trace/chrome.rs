//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! One process per rank (`pid` = world rank, `tid` = 0). Closed spans become
//! `B`/`E` duration-event pairs; eager post→wait send windows become async
//! `b`/`e` pairs (category `send-window`, globally unique ids) so the overlap
//! of in-flight sends with compute is visible as a separate track.
//!
//! Timestamps are microseconds relative to the earliest event in the trace,
//! emitted as raw floats — fractional microseconds are legal in the format
//! and keep distinct events from colliding on a tick.

use super::{Event, EventKind, RankTrace, Trace};
use crate::util::{json_array, json_num, JsonObj};
use std::collections::HashMap;

/// Serialize `trace` as a Chrome trace-event JSON document.
pub fn chrome_trace_json(trace: &Trace) -> String {
    let t_base = trace
        .ranks
        .iter()
        .flat_map(|r| r.events.iter())
        .map(|e| e.t0)
        .fold(f64::INFINITY, f64::min);
    let t_base = if t_base.is_finite() { t_base } else { 0.0 };

    let mut out: Vec<String> = Vec::with_capacity(trace.num_events() * 2 + trace.ranks.len());
    let mut next_window_id: u64 = 0;
    for rank in &trace.ranks {
        out.push(
            JsonObj::new()
                .str("name", "process_name")
                .str("ph", "M")
                .int("pid", rank.rank as u64)
                .int("tid", 0)
                .raw(
                    "args",
                    &JsonObj::new().str("name", &format!("rank {}", rank.rank)).build(),
                )
                .build(),
        );
        let mut events: Vec<(f64, String)> = sync_events(rank, t_base);
        events.extend(window_events(rank, t_base, &mut next_window_id));
        // Stable: keeps B-before-E (and b-before-e) at equal timestamps.
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        out.extend(events.into_iter().map(|(_, j)| j));
    }
    JsonObj::new()
        .raw("traceEvents", &json_array(out))
        .str("displayTimeUnit", "ms")
        .build()
}

fn us(t: f64, t_base: f64) -> f64 {
    (t - t_base) * 1e6
}

fn begin_event(rank: usize, ev: &Event, t_base: f64) -> (f64, String) {
    let ts = us(ev.t0, t_base);
    let mut args = JsonObj::new().int("seq", ev.seq);
    if let Some(n) = ev.node {
        args = args.int("node", n as u64);
    }
    if let Some(s) = ev.stage {
        args = args.int("stage", s as u64);
    }
    if let Some(m) = ev.mb {
        args = args.int("mb", m as u64);
    }
    if let Some(e) = ev.edge {
        args = args.int("edge", e as u64);
    }
    if let Some(p) = ev.peer {
        args = args.int("peer", p as u64);
    }
    if let Some(h) = ev.handle {
        args = args.int("handle", h as u64);
    }
    if let Some(b) = ev.bytes {
        args = args.int("bytes", b);
    }
    if let Some(l) = &ev.label {
        args = args.str("label", l);
    }
    let json = JsonObj::new()
        .str("name", ev.kind.name())
        .str("cat", ev.kind.category())
        .str("ph", "B")
        .int("pid", rank as u64)
        .int("tid", 0)
        .raw("ts", &json_num(ts))
        .raw("args", &args.build())
        .build();
    (ts, json)
}

fn end_event(rank: usize, ev: &Event, t_base: f64) -> (f64, String) {
    let ts = us(ev.t1, t_base);
    let json = JsonObj::new()
        .str("name", ev.kind.name())
        .str("ph", "E")
        .int("pid", rank as u64)
        .int("tid", 0)
        .raw("ts", &json_num(ts))
        .build();
    (ts, json)
}

/// Emit `B`/`E` pairs for one rank's closed spans. Spans from a single-rank
/// interpreter are properly nested (children close before parents), so
/// sorting by `(t0 asc, t1 desc, seq asc)` and popping finished spans off a
/// stack yields a nesting-correct, timestamp-ordered stream.
fn sync_events(rank: &RankTrace, t_base: f64) -> Vec<(f64, String)> {
    let mut idx: Vec<usize> = (0..rank.events.len()).collect();
    idx.sort_by(|&a, &b| {
        let (ea, eb) = (&rank.events[a], &rank.events[b]);
        ea.t0
            .total_cmp(&eb.t0)
            .then(eb.t1.total_cmp(&ea.t1))
            .then(ea.seq.cmp(&eb.seq))
    });
    let mut out = Vec::with_capacity(idx.len() * 2);
    let mut stack: Vec<usize> = Vec::new();
    for &i in &idx {
        let ev = &rank.events[i];
        while let Some(&top) = stack.last() {
            if rank.events[top].t1 <= ev.t0 {
                out.push(end_event(rank.rank, &rank.events[top], t_base));
                stack.pop();
            } else {
                break;
            }
        }
        out.push(begin_event(rank.rank, ev, t_base));
        stack.push(i);
    }
    while let Some(top) = stack.pop() {
        out.push(end_event(rank.rank, &rank.events[top], t_base));
    }
    out
}

/// Async `b`/`e` spans for eager post→wait send windows: a window opens at
/// the `PostSend*` IR span's start and closes at the paired `WaitSend`'s end.
/// Handles recycle across steps, so pairing walks the buffer in logical
/// order; each completed window gets a fresh globally-unique id.
fn window_events(rank: &RankTrace, t_base: f64, next_id: &mut u64) -> Vec<(f64, String)> {
    let mut open: HashMap<usize, &Event> = HashMap::new();
    let mut out = Vec::new();
    for ev in &rank.events {
        match ev.kind {
            EventKind::PostSendActivation | EventKind::PostSendError => {
                if let Some(h) = ev.handle {
                    open.insert(h, ev);
                }
            }
            EventKind::WaitSend => {
                let Some(post) = ev.handle.and_then(|h| open.remove(&h)) else {
                    continue;
                };
                let id = *next_id;
                *next_id += 1;
                let half = |ph: &str, t: f64, from: &Event| {
                    let ts = us(t, t_base);
                    let mut obj = JsonObj::new()
                        .str("name", "send-window")
                        .str("cat", "send-window")
                        .str("ph", ph)
                        .int("id", id)
                        .int("pid", rank.rank as u64)
                        .int("tid", 0)
                        .raw("ts", &json_num(ts));
                    if ph == "b" {
                        let mut args = JsonObj::new().int("seq", from.seq);
                        if let Some(e) = from.edge {
                            args = args.int("edge", e as u64);
                        }
                        if let Some(m) = from.mb {
                            args = args.int("mb", m as u64);
                        }
                        if let Some(h) = from.handle {
                            args = args.int("handle", h as u64);
                        }
                        if let Some(b) = from.bytes {
                            args = args.int("bytes", b);
                        }
                        obj = obj.raw("args", &args.build());
                    }
                    (ts, obj.build())
                };
                out.push(half("b", post.t0, post));
                out.push(half("e", ev.t1, post));
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RankTrace;

    fn ev(kind: EventKind, t0: f64, t1: f64) -> Event {
        let mut e = Event::span(kind);
        e.t0 = t0;
        e.t1 = t1;
        e
    }

    #[test]
    fn nested_spans_emit_balanced_ordered_pairs() {
        let mut r = RankTrace::new(0);
        // Interpreter order: children recorded before parents.
        r.push(ev(EventKind::Exec, 1.0, 2.0));
        r.push(ev(EventKind::FwdCompute, 0.5, 2.5));
        r.push(ev(EventKind::OptStep, 3.0, 4.0));
        let json = chrome_trace_json(&Trace { ranks: vec![r] });
        let chk = super::super::validate::validate_chrome_trace(&json).unwrap();
        assert_eq!(chk.ranks, 1);
        assert_eq!(chk.spans, 3);
        assert_eq!(chk.windows, 0);
        // fwd opens before its nested exec.
        let fwd = json.find("\"name\":\"fwd\"").unwrap();
        let exec = json.find("\"name\":\"exec\"").unwrap();
        assert!(fwd < exec);
    }

    #[test]
    fn post_wait_pairs_become_async_windows() {
        let mut r = RankTrace::new(2);
        r.push(ev(EventKind::PostSendActivation, 0.0, 0.1).handle(0).edge(1).mb(0).bytes(64));
        r.push(ev(EventKind::FwdCompute, 0.1, 0.9));
        r.push(ev(EventKind::WaitSend, 0.9, 1.0).handle(0));
        let json = chrome_trace_json(&Trace { ranks: vec![r] });
        let chk = super::super::validate::validate_chrome_trace(&json).unwrap();
        assert_eq!(chk.windows, 1);
        assert!(json.contains("\"cat\":\"send-window\""));
    }
}
