//! Minimal offline stand-in for the `anyhow` crate, covering exactly the
//! API subset this repository uses: [`Error`], [`Result`], and the
//! `anyhow!` / `bail!` / `ensure!` macros. The build is fully offline
//! (no crates.io access), so the real crate cannot be fetched; this
//! drop-in keeps every call site unchanged.
//!
//! Differences from the real crate: no backtraces, no downcasting, no
//! `Context` trait (unused here). `Error` stores a formatted message and
//! converts from any `std::error::Error` via `From`, which is what makes
//! the `?` operator work on io/parse errors throughout the crate.

use std::fmt;

/// A string-backed error type with the `anyhow::Error` surface this repo
/// needs. Intentionally does NOT implement `std::error::Error`, so the
/// blanket `From` below cannot conflict with the identity `From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from an already-formatted message (used by the
    /// `anyhow!` macro).
    pub fn from_msg(msg: String) -> Error {
        Error { msg }
    }

    /// Mirror of `anyhow::Error::msg`.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the message (no cause chain here).
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result` with the defaulted error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::from_msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_roundtrip() {
        fn f(x: i32) -> crate::Result<i32> {
            crate::ensure!(x > 0, "x must be positive, got {x}");
            if x == 13 {
                crate::bail!("unlucky {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(2).unwrap(), 4);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(13).unwrap_err().to_string(), "unlucky 13");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> crate::Result<usize> {
            let n: usize = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
    }
}
